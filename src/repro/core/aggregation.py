"""Server-side aggregation rules for heterogeneous-rank FedLoRA.

Implements the paper's method and every baseline it compares against
(Table 1), all over one stacked-factor representation:

  bs    (M, d, r_max)   client B factors, zero-padded above r_k
  as_   (M, r_max, n)   client A factors, zero-padded below r_k
  ranks (M,)            client ranks
  n_k   (M,)            client sample counts

Methods
  fedavg    -- homogeneous FedAvg of factors (FedIT); requires equal ranks
  hetlora   -- zero-pad, average B and A SEPARATELY (aggregation bias!)
  flora     -- stacking: dW = sum w_k B_k A_k merged into the base weights,
               adapters re-initialized (cold start) -- bias-free, expensive
  flexlora  -- dW = sum (n_k/N) B_k A_k, SVD realloc (rank collapse!)
  raflora   -- rank-partitioned dW (Eq. 8), SVD realloc  <- the paper

``backend="dense"`` materializes dW (paper-faithful); ``backend="factored"``
uses the QR low-rank SVD (beyond-paper, bit-compatible up to float error);
``backend="kernel"`` is the fused Pallas path (TPU kernels, interpret-mode
on CPU): sqrt-weighted U_c/V_c stacks + (R, R) Gram cores on-chip feeding
``svd_realloc_gram`` -- O((d+n)R) memory, dW never materialized, on every
engine including the sharded one (DESIGN.md §4.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import functools

from repro.core import partitions as parts
from repro.core.svd import (check_fallback_globals, dense_fallback_term,
                            dense_from_weighted, factored_append_fallback,
                            factored_from_weighted, factored_stack_batched,
                            svd_realloc_dense, svd_realloc_factored,
                            svd_realloc_gram)


@dataclass
class AggregationResult:
    b_g: jnp.ndarray                  # (d, r_max)
    a_g: jnp.ndarray                  # (r_max, n)
    sigma: Optional[jnp.ndarray]      # singular values (r_max,) or None
    merge_delta: Optional[jnp.ndarray] = None  # FLoRA: dW folded into base


def _dq(x):
    """Dequantize a transport ``QuantFactor`` to f32 (duck-typed so this
    core module never imports ``repro.federation``); plain factor arrays
    pass through untouched. The single dequantization point of every
    stack-build path -- all weighting (omega rows, staleness discounts,
    the Eq. 8 fallback) happens downstream on dequantized values, so the
    aggregation math is byte-layout-agnostic (DESIGN.md §12)."""
    if hasattr(x, "q") and hasattr(x, "scale"):
        return x.q.astype(jnp.float32) * x.scale
    return x


def _leading(x) -> int:
    """Leading-axis length of a factor that may be a QuantFactor."""
    return (x.q if hasattr(x, "q") else x).shape[0]


def pad_stack(factors: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
              r_max: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[(B_k (d, r_k), A_k (r_k, n))] -> padded stacks (M,d,r_max),(M,r_max,n).

    Entries may be transport-quantized (QuantFactor pairs): the sequential
    reference path dequantizes here, at stack-build time."""
    bs, as_ = [], []
    for b, a in factors:
        b, a = _dq(b), _dq(a)
        r = b.shape[-1]
        pad_b = [(0, 0)] * b.ndim
        pad_b[-1] = (0, r_max - r)
        pad_a = [(0, 0)] * a.ndim
        pad_a[-2] = (0, r_max - r)
        bs.append(jnp.pad(b, pad_b))
        as_.append(jnp.pad(a, pad_a))
    return jnp.stack(bs), jnp.stack(as_)


def _weights(n_k: Sequence[float]) -> np.ndarray:
    n = np.asarray(n_k, dtype=np.float64)
    return n / n.sum()


def staleness_discount(n_k: Sequence[float],
                       staleness: Optional[Sequence[int]],
                       gamma: float = 1.0) -> np.ndarray:
    """Staleness-discounted effective sample counts for async aggregation.

    Client k whose update is ``staleness[k]`` aggregations old contributes
    with ``n_k * gamma**staleness[k]`` -- the discount folds into the
    n_k-DERIVED weights (FedAvg weights, FlexLoRA/raFLoRA omega rows, DoRA
    magnitude weights) BEFORE their normalization, so:

    * totals are preserved: every weight family normalizes over the
      discounted counts, so the weights of a fixed client set sum to the
      same total as the synchronous round (no silent global down-weighting
      -- staleness only shifts RELATIVE mass toward fresher clients);
    * ghost clients (n_k = 0) stay exactly zero;
    * the raFLoRA effective-contributor sets and the Eq. 8 fallback are
      untouched (membership is rank-based, not weight-based).

    ``staleness=None``, ``gamma=1``, or an all-zero staleness vector are
    exact no-ops (the input counts are returned unscaled), which is what
    makes ``pipeline_depth=1`` reduce bit-level to the batched engine.
    """
    from repro.analysis import host_cost
    host_cost.tick("agg/weight_counts", len(n_k))
    n = np.asarray(n_k, dtype=np.float64)
    if staleness is None or gamma == 1.0:
        return n
    s = np.broadcast_to(np.asarray(staleness, dtype=np.float64), n.shape)
    if not s.any():
        return n
    assert gamma > 0.0, gamma  # gamma<=0 would zero real clients
    return n * np.power(float(gamma), s)


def cohort_weights(n_k: Sequence[float],
                   staleness: Optional[Sequence[int]],
                   present: Optional[Sequence[bool]],
                   gamma: float = 1.0) -> np.ndarray:
    """Normalized per-client aggregation weights of one buffered cohort.

    The SINGLE host-side weight rule of every grouped engine: staleness-
    discounted effective counts (``staleness_discount``), absent clients
    (event-driven ``present`` mask) and ghost clients (n_k = 0) forced to
    exactly zero, normalized to sum to 1 over the cohort. The protocol
    checker (``analysis/protocol.py``) calls this same function at every
    model-checked trigger firing, so a weight-conservation violation there
    is a finding against the implementation, not against a re-derivation.
    """
    w = staleness_discount(n_k, staleness, gamma)
    if present is not None:
        w = np.where(np.asarray(present, dtype=bool), w, 0.0)
    total = w.sum()
    assert total > 0.0, "a cohort aggregated with zero total weight"
    return w / total


# ---------------------------------------------------------------------------
# aggregation rules
# ---------------------------------------------------------------------------

def weighted_avg(stack, w):
    """Weighted average over the leading client axis (any batch axes). The
    single implementation behind every plain-FedAvg reduction -- factor
    stacks AND DoRA magnitudes, eager AND jitted."""
    wshape = (-1,) + (1,) * (stack.ndim - 1)
    return (w.reshape(wshape) * stack).sum(0)


def _avg_factors(bs, as_, w):
    """Weighted client-axis average of both factor stacks (fedavg/hetlora)."""
    return weighted_avg(bs, w), weighted_avg(as_, w)


def _flora_delta(bs, as_, w):
    """FLoRA stacking math: unbiased dW + zeroed (cold-start) adapters.
    The single implementation behind flora, eager AND jitted."""
    dw = jnp.einsum("m,m...dr,m...rn->...dn", w.astype(jnp.float32),
                    bs.astype(jnp.float32), as_.astype(jnp.float32))
    # cold start: fresh (zero) global adapter; dW returned for base merge
    return (jnp.zeros(bs.shape[1:], jnp.float32),
            jnp.zeros(as_.shape[1:], jnp.float32), dw)


def aggregate_fedavg(bs, as_, ranks, n_k) -> AggregationResult:
    """Homogeneous FedAvg of the raw factors (FedIT). Biased mixing of
    B and A -- included as the homogeneous baseline."""
    ranks = np.asarray(ranks)
    assert (ranks == ranks[0]).all(), "fedavg requires homogeneous ranks"
    b_g, a_g = _avg_factors(bs, as_, jnp.asarray(_weights(n_k),
                                                 dtype=bs.dtype))
    return AggregationResult(b_g, a_g, None)


def aggregate_hetlora(bs, as_, ranks, n_k) -> AggregationResult:
    """HetLoRA: zero-padding alignment, separate averaging of B and A.
    E[B]E[A] != E[BA] -- the aggregation bias the later methods remove."""
    b_g, a_g = _avg_factors(bs, as_, jnp.asarray(_weights(n_k),
                                                 dtype=bs.dtype))
    return AggregationResult(b_g, a_g, None)


def aggregate_flora(bs, as_, ranks, n_k) -> AggregationResult:
    """FLoRA: stacking-based, bias-free. The aggregate dW = sum w_k B_k A_k
    is merged into the base weights and adapters restart from scratch
    (cold start). Communication cost O(M (d+n) r) is charged by the cost
    model in benchmarks/bench_cost.py."""
    w = jnp.asarray(_weights(n_k), dtype=jnp.float32)
    b_g, a_g, dw = _flora_delta(bs, as_, w)
    return AggregationResult(b_g, a_g, None, merge_delta=dw)


def aggregate_flexlora(bs, as_, ranks, n_k, *, backend: str = "factored"
                       ) -> AggregationResult:
    """FlexLoRA: rank-agnostic weighted sum + SVD realloc (Eqs. 2-4)."""
    r_max = bs.shape[-1]
    omega = jnp.asarray(parts.omega_flexlora(ranks, n_k, r_max))
    return _weighted_svd(bs, as_, omega, None, None, None, r_max, backend)


def aggregate_raflora(bs, as_, ranks, n_k, *, rank_levels: Sequence[int],
                      global_b=None, global_a=None,
                      backend: str = "factored") -> AggregationResult:
    """raFLoRA: rank-partitioned aggregation (Eq. 8 / Algorithm 1)."""
    r_max = max(rank_levels)
    omega_np, fallback_np = parts.omega_raflora(ranks, n_k, rank_levels)
    omega = jnp.asarray(omega_np)
    fallback = jnp.asarray(fallback_np)
    if not np.any(fallback_np):
        fallback = None
    return _weighted_svd(bs, as_, omega, global_b, global_a, fallback,
                         r_max, backend)


def _weighted_svd(bs, as_, omega, global_b, global_a, fallback, r_max,
                  backend) -> AggregationResult:
    """Weighted-diagonal contraction + SVD realloc.

    Accepts unstacked factors (M, d, r) or factors with ANY number of batch
    axes between the client axis and the matrix axes -- (M, L, d, r) layer
    stacks from lax.scan models, (M, P, L, d, r) shape buckets from the
    batched round engine. Dense/factored backends vmap the pipeline over
    each batch axis in turn; the kernel backend flattens the batch axes and
    lowers the whole bucket through the fused layer-batched Pallas grids
    (stack + Gram cores, never dW -- ``_agg_kernel_stacked``).
    """
    check_fallback_globals(fallback, global_b, global_a)
    if bs.ndim > 3:
        if backend == "kernel":
            return _agg_kernel_stacked(bs, as_, omega, global_b,
                                       global_a, fallback, r_max)
        def one_slice(bs_l, as_l, gb_l, ga_l):
            res = _weighted_svd(bs_l, as_l, omega, gb_l, ga_l, fallback,
                                r_max, backend)
            sig = res.sigma if res.sigma is not None else jnp.zeros((r_max,))
            return res.b_g, res.a_g, sig
        gb = global_b if global_b is not None else \
            jnp.zeros(bs.shape[1:-1] + (r_max,), jnp.float32)
        ga = global_a if global_a is not None else \
            jnp.zeros(as_.shape[1:-2] + (r_max, as_.shape[-1]), jnp.float32)
        b_g, a_g, sigma = jax.vmap(one_slice, in_axes=(1, 1, 0, 0))(
            bs, as_, gb, ga)
        return AggregationResult(b_g, a_g, sigma)
    if backend == "dense":
        dw = dense_from_weighted(bs, as_, omega, global_b, global_a, fallback)
        b_g, a_g, sigma = svd_realloc_dense(dw, r_max)
    elif backend == "factored":
        u_c, v_c = factored_from_weighted(bs, as_, omega, global_b, global_a,
                                          fallback)
        b_g, a_g, sigma = svd_realloc_factored(u_c, v_c, r_max)
    elif backend == "kernel":
        from repro.kernels import ops as kernel_ops
        u_c, v_c, g_u, g_v = kernel_ops.factored_stack_gram(
            bs, as_, omega, global_b, global_a, fallback)
        b_g, a_g, sigma = svd_realloc_gram(u_c, v_c, g_u, g_v, r_max)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return AggregationResult(b_g, a_g, sigma)


def _agg_kernel_stacked(bs, as_, omega, global_b, global_a,
                        fallback, r_max) -> AggregationResult:
    """Kernel backend for batch-stacked factors: flatten every batch axis
    into one layer axis, run the fused layer-batched Pallas grids once
    (sqrt-weighted U_c/V_c stacks + (R, R) Gram cores -- DESIGN.md §4.3,
    the Eq. 8 fallback riding as one extra client), then one batched
    Gram-core SVD realloc. dW (L, d, n) is never materialized."""
    from repro.kernels import ops as kernel_ops
    lead = bs.shape[1:-2]                     # batch axes after clients
    m, d, r = bs.shape[0], bs.shape[-2], bs.shape[-1]
    n = as_.shape[-1]
    layers = int(np.prod(lead))
    bs_l = jnp.moveaxis(bs.reshape(m, layers, d, r), 0, 1)
    as_l = jnp.moveaxis(as_.reshape(m, layers, r, n), 0, 1)
    gb = None if global_b is None else global_b.reshape(layers, d, r_max)
    ga = None if global_a is None else global_a.reshape(layers, r_max, n)
    u_c, v_c, g_u, g_v = kernel_ops.factored_stack_gram_layered(
        bs_l, as_l, omega, gb, ga, fallback)
    b_g, a_g, sigma = jax.vmap(
        functools.partial(svd_realloc_gram, r_max=r_max))(u_c, v_c, g_u, g_v)
    return AggregationResult(b_g.reshape(lead + (d, r_max)),
                             a_g.reshape(lead + (r_max, n)),
                             sigma.reshape(lead + (r_max,)))


# ---------------------------------------------------------------------------
# method registry + per-adapter driver
# ---------------------------------------------------------------------------

METHODS = ("fedavg", "hetlora", "flora", "flexlora", "raflora", "ffa")


def aggregate_ffa(bs, as_, ranks, n_k, *, global_b) -> AggregationResult:
    """FFA-LoRA (paper ref [9]): the random-init DOWN factor is FROZEN at
    its shared global value; only the UP factor is trained and averaged --
    removes the E[B]E[A] != E[BA] bias in the homogeneous setting.

    Layout note: the server maps model lora_a -> first factor here, so the
    FROZEN factor is ``bs``/``global_b`` and the averaged one is ``as_``.
    Heterogeneous ranks: zero-padded averaging (HetLoRA-style) on the
    trained factor.
    """
    a_g = weighted_avg(as_, jnp.asarray(_weights(n_k), dtype=as_.dtype))
    return AggregationResult(global_b, a_g, None)


# -- jitted whole-bucket pipelines (batched round engine) -------------------
#
# The sequential reference path runs the rules above eagerly, one adapter at
# a time. The batched engine instead stacks every same-shape adapter into
# one (M, P, ..., d, r) bucket and pushes the whole bucket through ONE jitted
# call -- including the stack/pad/concatenate assembly -- so per-op Python
# dispatch is paid once per bucket per round.

def _dispatch_stacked(bs, as_, warg, global_b, global_a, fallback, r_max,
                      backend, method):
    """Traced method dispatch over pre-stacked factors.

    Returns (b_g, a_g, sigma|None, merge_delta|None); ``warg`` is the
    client-weight vector (avg family) or the omega matrix (SVD family).
    """
    if method in ("fedavg", "hetlora", "ffa"):
        w = warg.astype(bs.dtype)
        a_g = weighted_avg(as_, w)
        if method == "ffa":           # frozen factor: keep the global value
            return global_b, a_g, None, None
        return weighted_avg(bs, w), a_g, None, None
    if method == "flora":
        b_g, a_g, dw = _flora_delta(bs, as_, warg)
        return b_g, a_g, None, dw
    res = _weighted_svd(bs, as_, warg, global_b, global_a, fallback,
                        r_max, backend)
    return res.b_g, res.a_g, res.sigma, None


@functools.partial(jax.jit, static_argnames=("r_max", "backend", "method"))
def _stacked_core(bs, as_, warg, global_b, global_a, fallback, *,
                  r_max, backend, method):
    return _dispatch_stacked(_dq(bs), _dq(as_), warg, global_b, global_a,
                             fallback, r_max, backend, method)


def _pad_rank(x, r_max: int, axis: int):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r_max - x.shape[axis])
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("r_max", "backend", "method"))
def _grouped_core(group_bs, group_as, warg, global_bs, global_as, fallback,
                  *, r_max, backend, method):
    """Assemble a shape bucket from per-rank-group factor tuples and
    aggregate it, all inside one XLA program.

    group_bs: tuple over rank groups of tuples over bucket adapters of
    (G, ..., d, r_group) arrays (group_as analogous); global_bs/global_as:
    tuples over bucket adapters of (..., d, r_max)/(..., r_max, n).
    Transport-quantized entries (QuantFactor) dequantize here, once, at
    stack-build time.
    """
    bs = jnp.concatenate(
        [_pad_rank(jnp.stack([_dq(b) for b in bt], axis=1), r_max, -1)
         for bt in group_bs])                         # (M, P, ..., d, r_max)
    as_ = jnp.concatenate(
        [_pad_rank(jnp.stack([_dq(a) for a in at], axis=1), r_max, -2)
         for at in group_as])                         # (M, P, ..., r_max, n)
    gb = None if global_bs is None else jnp.stack(global_bs)
    ga = None if global_as is None else jnp.stack(global_as)
    return _dispatch_stacked(bs, as_, warg, gb, ga, fallback, r_max,
                             backend, method)


# -- sharded whole-bucket pipelines (sharded round engine) -------------------
#
# DESIGN.md §5: with clients sharded over the mesh's ``data`` axis, every
# reduction this family performs -- plain weighted factor averages, FLoRA's
# dW stacking, and the weighted-diagonal contraction behind the SVD-realloc
# methods -- becomes a per-shard partial sum followed by ONE ``jax.lax.psum``.
# The dense family all-reduces the (..., d, n) contraction; the factored
# AND kernel families all-reduce the zero-scattered (d, R) / (R, n) factor
# stack (each shard writes its own column block, so the psum is an
# all-gather in disguise and the reduced stack equals the single-device one
# up to client ordering, which the SVD does not see) -- the kernel backend
# builds its shard-local block with the layered Pallas stack grid over
# resident clients only (DESIGN.md §4.3). The SVD reallocation itself is
# the UNCHANGED single-device math (``svd_realloc_dense`` /
# ``svd_realloc_factored`` / the Pallas-Gram ``svd_realloc_gram``) applied
# to the reduced, replicated result.

def _realloc_dense_lead(dw, r_max):
    """Batched ``svd_realloc_dense`` over any leading bucket/layer axes."""
    lead, (d, n) = dw.shape[:-2], dw.shape[-2:]
    b, a, s = jax.vmap(functools.partial(svd_realloc_dense, r_max=r_max))(
        dw.reshape((-1, d, n)))
    return (b.reshape(lead + (d, r_max)), a.reshape(lead + (r_max, n)),
            s.reshape(lead + (r_max,)))


def _realloc_factored_lead(u_c, v_c, r_max):
    """Batched ``svd_realloc_factored`` over any leading bucket/layer axes."""
    lead = u_c.shape[:-2]
    d, rr = u_c.shape[-2:]
    n = v_c.shape[-1]
    b, a, s = jax.vmap(functools.partial(
        svd_realloc_factored, r_max=r_max))(
        u_c.reshape((-1, d, rr)), v_c.reshape((-1, rr, n)))
    return (b.reshape(lead + (d, r_max)), a.reshape(lead + (r_max, n)),
            s.reshape(lead + (r_max,)))


def _realloc_gram_lead(u_c, v_c, g_u, g_v, r_max):
    """Batched ``svd_realloc_gram`` over any leading bucket/layer axes."""
    lead = u_c.shape[:-2]
    d, rr = u_c.shape[-2:]
    n = v_c.shape[-1]
    b, a, s = jax.vmap(functools.partial(
        svd_realloc_gram, r_max=r_max))(
        u_c.reshape((-1, d, rr)), v_c.reshape((-1, rr, n)),
        g_u.reshape((-1, rr, rr)), g_v.reshape((-1, rr, rr)))
    return (b.reshape(lead + (d, r_max)), a.reshape(lead + (r_max, n)),
            s.reshape(lead + (r_max,)))


def _sharded_partial_quantized(group_bs, group_as, group_w, *, r_max,
                               axis, axes, axis_sizes):
    """Quantized factored/kernel partial: all-reduce the COMPRESSED bytes.

    Instead of dequantizing locally and psumming f32 stacks, each shard
    zero-scatters its raw int8/bf16 payload block into the full
    (…, d, S*width) stack -- mirroring ``factored_stack_batched``'s column
    layout exactly (column index = client*r_max + rank) -- together with a
    tiny f32 per-column weight vector folding ``scale * sqrt(omega)``.
    Disjoint blocks mean the payload psum is an all-gather in disguise
    (every position has exactly one nonzero contributor, so int8 never
    overflows), and the wire bytes drop by ~4x at int8 / 2x at bf16: the
    claim ``launch/fl_dryrun.py --transport`` verifies. Dequantization
    happens ONCE, after the reduction, so the returned (u_c, v_c) are the
    same f32 stacks the unquantized path reduces -- the Eq. 8 fallback
    append and the SVD realloc downstream are untouched, and the kernel
    backend shares this staging (its Gram grids consume the reduced,
    replicated stack exactly as in the unquantized sharded path).
    """
    qs = jnp.concatenate(
        [_pad_rank(jnp.stack([f.q for f in bt], axis=1), r_max, -1)
         for bt in group_bs])           # (m_loc, P, ..., d, r_max) payload
    sb = jnp.concatenate(
        [_pad_rank(jnp.stack([f.scale for f in bt], axis=1), r_max, -1)
         for bt in group_bs])           # (m_loc, P, ..., 1, r_max) f32
    qa = jnp.concatenate(
        [_pad_rank(jnp.stack([f.q for f in at], axis=1), r_max, -2)
         for at in group_as])           # (m_loc, P, ..., r_max, n) payload
    sa = jnp.concatenate(
        [_pad_rank(jnp.stack([f.scale for f in at], axis=1), r_max, -2)
         for at in group_as])           # (m_loc, P, ..., r_max, 1) f32
    w = jnp.concatenate(group_w)        # (m_loc, r_max) omega rows
    m, r = qs.shape[0], qs.shape[-1]
    lead = qs.shape[1:-2]
    sq = jnp.sqrt(jnp.maximum(w, 0.0)).astype(jnp.float32)
    sqr = sq.reshape((m,) + (1,) * len(lead) + (r,))
    colw_u = sb[..., 0, :] * sqr        # (m, *lead, r): scale * sqrt(omega)
    colw_v = sa[..., 0] * sqr
    # factored_stack_batched layout: column index = client*r_max + rank
    u_pay = jnp.moveaxis(qs, 0, -2).reshape(lead + (qs.shape[-2], m * r))
    v_pay = jnp.moveaxis(qa, 0, -3).reshape(lead + (m * r, qa.shape[-1]))
    cu = jnp.moveaxis(colw_u, 0, -2).reshape(lead + (m * r,))
    cv = jnp.moveaxis(colw_v, 0, -2).reshape(lead + (m * r,))
    width = m * r
    shard_idx = jnp.int32(0)            # flat shard index over the axes
    n_shards = 1
    for a, size in zip(axes, axis_sizes):
        shard_idx = shard_idx * size + jax.lax.axis_index(a)
        n_shards *= size
    off = shard_idx * width

    def scatter(x, ax):
        shape = list(x.shape)
        shape[ax] = n_shards * width
        full = jnp.zeros(tuple(shape), x.dtype)
        return jax.lax.dynamic_update_slice_in_dim(full, x, off, axis=ax)

    u_full = jax.lax.psum(scatter(u_pay, -1), axis)
    v_full = jax.lax.psum(scatter(v_pay, -2), axis)
    cu_full = jax.lax.psum(scatter(cu, -1), axis)
    cv_full = jax.lax.psum(scatter(cv, -1), axis)
    u_c = u_full.astype(jnp.float32) * cu_full[..., None, :]
    v_c = v_full.astype(jnp.float32) * cv_full[..., :, None]
    return u_c, v_c


def _sharded_partial(group_bs, group_as, group_w, gb, ga, *, r_max,
                     backend, method, axes, axis_sizes):
    """Per-shard body (runs INSIDE shard_map): assemble the shard's local
    client block of the bucket, compute its partial reduction, psum.

    ``group_w`` carries the per-group client weight vectors (avg family) or
    omega matrix rows (SVD family) already zeroed for ghost clients, sharded
    along the client axis exactly like the factor stacks, so each shard
    weights only its resident clients. ``axes`` is the tuple of mesh axes
    the client axis is sharded over (the live engine's 1-D mesh uses
    ``("data",)``; the multi-pod dry run uses ``("pod", "data")`` so the
    pod axis shares the work instead of replicating it).
    """
    axis = axes if len(axes) > 1 else axes[0]
    quantized = any(hasattr(b, "q") for bt in group_bs for b in bt)
    svd_family = method not in ("fedavg", "hetlora", "ffa", "flora")
    if quantized and svd_family and backend in ("factored", "kernel"):
        # quantized collective: psum the raw int8/bf16 payload blocks plus
        # a tiny f32 per-column weight vector; dequantize AFTER the
        # reduction (DESIGN.md §12)
        return _sharded_partial_quantized(
            group_bs, group_as, group_w, r_max=r_max, axis=axis,
            axes=axes, axis_sizes=axis_sizes)
    if quantized:
        # avg family / flora / dense backend consume full-precision stacks
        # before their reduction -- dequantize locally (no collective-byte
        # saving on these paths; documented in DESIGN.md §12)
        group_bs = tuple(tuple(_dq(b) for b in bt) for bt in group_bs)
        group_as = tuple(tuple(_dq(a) for a in at) for at in group_as)
    bs = jnp.concatenate([_pad_rank(jnp.stack(bt, axis=1), r_max, -1)
                          for bt in group_bs])        # (m_loc, P, ..., d, r)
    as_ = jnp.concatenate([_pad_rank(jnp.stack(at, axis=1), r_max, -2)
                           for at in group_as])       # (m_loc, P, ..., r, n)
    w = jnp.concatenate(group_w)
    if method in ("fedavg", "hetlora", "ffa"):
        wc = w.astype(bs.dtype)
        a_g = jax.lax.psum(weighted_avg(as_, wc), axis)
        if method == "ffa":           # frozen factor: keep the global value
            return gb, a_g
        return jax.lax.psum(weighted_avg(bs, wc), axis), a_g
    if method == "flora":
        b_g, a_g, dw = _flora_delta(bs, as_, w)
        return b_g, a_g, jax.lax.psum(dw, axis)
    # SVD family: w is the (m_loc, r_max) omega matrix. Both low-rank
    # backends reduce the zero-scattered (d+n, R) stack -- the factored
    # backend builds its shard-local block with jnp, the kernel backend
    # with the layered Pallas stack grid over the shard's RESIDENT clients
    # only (DESIGN.md §4.3); the collective stays ONE psum per bucket.
    if backend in ("factored", "kernel"):
        if backend == "kernel":
            from repro.kernels import ops as kernel_ops
            u_loc, v_loc = kernel_ops.factored_stack_lead(bs, as_, w)
        else:
            u_loc, v_loc = factored_stack_batched(bs, as_, w)
        width = u_loc.shape[-1]
        shard_idx = jnp.int32(0)        # flat shard index over the axes
        n_shards = 1
        for a, size in zip(axes, axis_sizes):
            shard_idx = shard_idx * size + jax.lax.axis_index(a)
            n_shards *= size
        off = shard_idx * width
        u_full = jnp.zeros(u_loc.shape[:-1] + (n_shards * width,),
                           u_loc.dtype)
        v_full = jnp.zeros(v_loc.shape[:-2] + (n_shards * width,)
                           + v_loc.shape[-1:], v_loc.dtype)
        u_full = jax.lax.dynamic_update_slice_in_dim(u_full, u_loc, off,
                                                     axis=-1)
        v_full = jax.lax.dynamic_update_slice_in_dim(v_full, v_loc, off,
                                                     axis=-2)
        return jax.lax.psum(u_full, axis), jax.lax.psum(v_full, axis)
    # dense: the paper-faithful (..., d, n) all-reduce
    dw = jnp.einsum("m...dr,mr,m...rn->...dn", bs.astype(jnp.float32),
                    w.astype(jnp.float32), as_.astype(jnp.float32))
    return jax.lax.psum(dw, axis)


_SHARDED_FN_CACHE: Dict[tuple, "object"] = {}


def sharded_grouped_fn(mesh, r_max: int, backend: str, method: str,
                       axes: Tuple[str, ...] = ("data",)):
    """The jitted sharded-bucket pipeline for one (mesh, method, backend).

    Signature: fn(group_bs, group_as, group_w, global_bs, global_as,
    fallback) -> (b_g, a_g, sigma|None, merge_delta|None), mirroring
    ``_grouped_core`` but with every per-group array sharded over the
    mesh axes in ``axes`` on its leading client dimension (the live
    engine's 1-D FL mesh uses ``("data",)``; the multi-pod dry run shards
    over ``("pod", "data")``). Cached per key so repeated rounds reuse one
    compilation; also the lowering target of ``launch/fl_dryrun.py`` (the
    dry-run and the live engine share this exact program).
    """
    key = (mesh, r_max, backend, method, tuple(axes))
    if key in _SHARDED_FN_CACHE:
        return _SHARDED_FN_CACHE[key]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    axes = tuple(axes)
    axis_sizes = tuple(mesh.shape[a] for a in axes)
    partial_fn = functools.partial(
        _sharded_partial, r_max=r_max, backend=backend, method=method,
        axes=axes, axis_sizes=axis_sizes)

    def fn(group_bs, group_as, group_w, global_bs, global_as, fallback):
        from repro.sharding.specs import client_spec
        check_fallback_globals(fallback, global_bs, global_as)
        gb = None if global_bs is None else jnp.stack(global_bs)
        ga = None if global_as is None else jnp.stack(global_as)
        cl = client_spec(axes)
        red = shard_map(partial_fn, mesh=mesh,
                        in_specs=(cl, cl, cl, P(), P()),
                        out_specs=P(), check_rep=False)(
            group_bs, group_as, group_w, gb, ga)
        if method in ("fedavg", "hetlora", "ffa"):
            b_g, a_g = red
            return b_g, a_g, None, None
        if method == "flora":
            b_g, a_g, dw = red
            return b_g, a_g, None, dw
        if backend in ("factored", "kernel"):
            u_c, v_c = red
            if fallback is not None:
                # appended exactly once, AFTER the cross-shard reduction
                u_c, v_c = factored_append_fallback(u_c, v_c, gb, ga,
                                                    fallback)
            if backend == "kernel":
                # (R, R) Gram cores of the reduced, replicated stack via
                # the Pallas grids, then the Gram-core realloc -- the same
                # math as the single-host kernel path (DESIGN.md §4.3)
                from repro.kernels import ops as kernel_ops
                g_u, g_v = kernel_ops.factored_gram_lead(u_c, v_c)
                b_g, a_g, sigma = _realloc_gram_lead(u_c, v_c, g_u, g_v,
                                                     r_max)
            else:
                b_g, a_g, sigma = _realloc_factored_lead(u_c, v_c, r_max)
        else:
            dw = red
            if fallback is not None:
                dw = dw + dense_fallback_term(gb, ga, fallback)
            b_g, a_g, sigma = _realloc_dense_lead(dw, r_max)
        return b_g, a_g, sigma, None

    jitted = jax.jit(fn)
    _SHARDED_FN_CACHE[key] = jitted
    return jitted


@dataclass
class Aggregator:
    """Aggregates a round of client adapter uploads, layer by layer."""

    method: str
    rank_levels: Sequence[int]
    backend: str = "factored"
    # raFLoRA partial variants (Fig. 5a): apply effective-contributor
    # weighting only up to this boundary; higher partitions use FlexLoRA
    # weights. None = full raFLoRA.
    partial_up_to: Optional[int] = None

    def __post_init__(self):
        assert self.method in METHODS, self.method

    def aggregate_layer(self, factors, ranks, n_k, global_b=None,
                        global_a=None) -> AggregationResult:
        """factors: [(B_k (d, r_k), A_k (r_k, n))] for one adapter layer."""
        r_max = max(self.rank_levels)
        bs, as_ = pad_stack(factors, r_max)
        if self.method == "fedavg":
            return aggregate_fedavg(bs, as_, ranks, n_k)
        if self.method == "hetlora":
            return aggregate_hetlora(bs, as_, ranks, n_k)
        if self.method == "ffa":
            return aggregate_ffa(bs, as_, ranks, n_k, global_b=global_b)
        if self.method == "flora":
            return aggregate_flora(bs, as_, ranks, n_k)
        if self.method == "flexlora":
            return aggregate_flexlora(bs, as_, ranks, n_k,
                                      backend=self.backend)
        # raflora (optionally partial)
        if self.partial_up_to is None:
            return aggregate_raflora(
                bs, as_, ranks, n_k, rank_levels=self.rank_levels,
                global_b=global_b, global_a=global_a, backend=self.backend)
        return self._aggregate_partial(bs, as_, ranks, n_k, global_b, global_a)

    def _aggregate_partial(self, bs, as_, ranks, n_k, global_b, global_a
                           ) -> AggregationResult:
        """raFLoRA-a/b/c variants: rank-aware weights for partitions up to
        ``partial_up_to``; FlexLoRA weights above (Fig. 5a)."""
        omega, fallback = self._svd_weights(ranks, n_k)
        return _weighted_svd(bs, as_, jnp.asarray(omega), global_b, global_a,
                             None if fallback is None
                             else jnp.asarray(fallback),
                             max(self.rank_levels), self.backend)

    def _svd_weights(self, ranks, n_k):
        """Per-round (omega, fallback) numpy weights for the SVD-realloc
        family: flexlora, raflora, and the partial raFLoRA variants."""
        r_max = max(self.rank_levels)
        if self.method == "flexlora":
            return parts.omega_flexlora(ranks, n_k, r_max), None
        omega, fb = parts.omega_raflora(ranks, n_k, self.rank_levels)
        if self.partial_up_to is not None:
            om_flex = parts.omega_flexlora(ranks, n_k, r_max)
            cut = self.partial_up_to
            omega = np.concatenate([omega[:, :cut], om_flex[:, cut:]], axis=1)
            fb = np.concatenate([fb[:cut], np.zeros(r_max - cut)])
        return omega, (fb if fb.any() else None)

    def _weight_args(self, ranks, n_k):
        """(warg, fallback) inputs for ``_dispatch_stacked``.

        Returned as NUMPY: the jitted bucket pipelines transfer them at
        dispatch. Eager ``jnp.asarray`` here would synchronize with
        in-flight device work on the CPU client and stall the async round
        engine's dispatch pipeline."""
        if self.method == "fedavg":
            ranks_arr = np.asarray(ranks)
            assert (ranks_arr == ranks_arr[0]).all(), \
                "fedavg requires homogeneous ranks"
        if self.method in ("fedavg", "hetlora", "ffa", "flora"):
            return np.asarray(_weights(n_k), np.float32), None
        omega, fallback = self._svd_weights(ranks, n_k)
        return (np.asarray(omega),
                None if fallback is None else np.asarray(fallback))

    def aggregate_stack(self, bs, as_, ranks, n_k, global_b=None,
                        global_a=None) -> AggregationResult:
        """First-class batched API: aggregate a pre-stacked shape bucket.

        bs (M, *batch, d, r_max); as_ (M, *batch, r_max, n) with any batch
        axes (adapter bucket, scan-stacked layers, ...); global factors, if
        given, carry the same batch axes without the client axis. One jitted
        call per bucket. Returns an AggregationResult whose fields keep the
        batch axes.
        """
        warg, fallback = self._weight_args(ranks, n_k)
        b_g, a_g, sigma, dw = _stacked_core(
            bs, as_, warg, global_b, global_a, fallback,
            r_max=max(self.rank_levels), backend=self.backend,
            method=self.method)
        return AggregationResult(b_g, a_g, sigma, merge_delta=dw)

    def _present_weight_args(self, ranks, n_arr, present):
        """(warg, fallback) with only ``present`` clients participating.

        The event-driven engine aggregates PARTIAL cohorts (whoever has
        arrived when the trigger fires); absent clients must contribute
        exactly nothing AND stay out of every membership-derived quantity
        (raFLoRA effective-contributor sets, the Eq. 8 fallback mask, the
        fedavg homogeneity check) -- so weights are computed on the present
        subset only and scattered back with zeros, exactly the ghost-client
        rule of the sharded path. When every client is present this is
        bit-identical to the unfiltered path (same inputs, same arrays),
        which is what keeps the unit-latency event run equal to the
        cadence engine.
        """
        n_arr = np.where(np.asarray(present, dtype=bool), n_arr, 0.0)
        real = np.flatnonzero(n_arr > 0)
        assert real.size > 0, "an aggregation fired with no present client"
        warg_real, fallback = self._weight_args(
            [ranks[i] for i in real], n_arr[real])
        warg_np = np.asarray(warg_real)
        warg = np.zeros((len(n_arr),) + warg_np.shape[1:], warg_np.dtype)
        warg[real] = warg_np
        return warg, fallback

    def aggregate_grouped(self, group_bs, group_as, ranks, n_k,
                          global_bs=None, global_as=None,
                          staleness=None, gamma: float = 1.0,
                          present=None) -> AggregationResult:
        """Batched round engine hot path: aggregate a shape bucket straight
        from per-rank-group factor stacks.

        group_bs/group_as: sequences over rank groups of per-adapter factor
        sequences ((G, ..., d, r_group) / (G, ..., r_group, n)); ranks/n_k
        in concatenated group-client order; global_bs/global_as: per-adapter
        global factors. Bucket assembly (stack adapters, pad ranks,
        concatenate groups) AND aggregation run in one jitted dispatch.
        Returns an AggregationResult with a leading bucket-adapter axis.

        ``staleness``/``gamma``: the async round engine's staleness-
        discounted weighting (``staleness_discount``) -- per-client
        aggregation ages folded into the n_k-derived weights.

        ``present``: optional per-client participation mask (event-driven
        engine): absent clients get zero weight and are excluded from
        membership-derived weighting (``_present_weight_args``).
        """
        n_arr = staleness_discount(n_k, staleness, gamma)
        if present is not None:
            warg, fallback = self._present_weight_args(ranks, n_arr, present)
        else:
            warg, fallback = self._weight_args(ranks, n_arr)
        b_g, a_g, sigma, dw = _grouped_core(
            tuple(tuple(bt) for bt in group_bs),
            tuple(tuple(at) for at in group_as),
            warg,
            None if global_bs is None else tuple(global_bs),
            None if global_as is None else tuple(global_as),
            fallback, r_max=max(self.rank_levels), backend=self.backend,
            method=self.method)
        return AggregationResult(b_g, a_g, sigma, merge_delta=dw)

    def aggregate_grouped_sharded(self, group_bs, group_as, ranks, n_k,
                                  mesh, global_bs=None, global_as=None,
                                  staleness=None, gamma: float = 1.0,
                                  present=None) -> AggregationResult:
        """Sharded round engine hot path: ``aggregate_grouped`` with the
        client axis sharded over the mesh's ``data`` axis and every
        reduction backed by one ``jax.lax.psum`` (DESIGN.md §5).

        Inputs mirror ``aggregate_grouped`` except that each group's client
        axis must be padded to a multiple of the data-axis size and
        ``n_k[j] == 0`` marks a ghost (padding) client: weights and omega
        rows are computed from the REAL clients only and scattered with
        zeros at ghost positions, so ghosts contribute exactly nothing to
        any reduction AND leave the raFLoRA effective-contributor counts /
        Eq. 8 fallback untouched. ``staleness``/``gamma`` discount exactly
        as in ``aggregate_grouped`` (a ghost's discounted count is still 0);
        ``present`` additionally zeroes not-yet-arrived clients (the
        event-driven engine's partial cohorts ride the same ghost rule).
        """
        n_shards = mesh.shape["data"]
        sizes = [_leading(bt[0]) for bt in group_bs]
        assert all(g % n_shards == 0 for g in sizes), (sizes, n_shards)
        n_arr = staleness_discount(n_k, staleness, gamma)
        # ghosts and absent clients share ONE masking rule
        # (_present_weight_args): subset weights, scattered back with zeros
        warg, fallback = self._present_weight_args(
            ranks, n_arr,
            np.ones(len(n_arr), dtype=bool) if present is None else present)
        group_w = tuple(np.split(warg, np.cumsum(sizes)[:-1]))
        fn = sharded_grouped_fn(mesh, max(self.rank_levels), self.backend,
                                self.method)
        b_g, a_g, sigma, dw = fn(
            tuple(tuple(bt) for bt in group_bs),
            tuple(tuple(at) for at in group_as),
            group_w,
            None if global_bs is None else tuple(global_bs),
            None if global_as is None else tuple(global_as),
            fallback)
        return AggregationResult(b_g, a_g, sigma, merge_delta=dw)
