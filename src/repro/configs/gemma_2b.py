"""gemma-2b — dense, MQA (kv=1), GeGLU, head_dim=256. [arXiv:2403.08295]"""
from repro.configs.base import ACT_GEGLU, ModelConfig, register

GEMMA_2B = register(ModelConfig(
    name="gemma-2b",
    kind="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA on the 2b variant
    head_dim=256,              # explicit (> d_model/num_heads)
    d_ff=16384,
    vocab_size=256000,
    activation=ACT_GEGLU,
    rope_theta=10_000.0,
    tie_embeddings=True,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="Gemma-2B [arXiv:2403.08295]; GeGLU, head_dim=256, MQA",
))
