"""qwen2-7b — dense, GQA kv=4, QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ACT_SWIGLU, ModelConfig, register

QWEN2_7B = register(ModelConfig(
    name="qwen2-7b",
    kind="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,            # GQA kv=4
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation=ACT_SWIGLU,
    qkv_bias=True,             # QKV bias per assignment
    rope_theta=1_000_000.0,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="Qwen2-7B [arXiv:2407.10671]; GQA kv=4, QKV bias",
))
