"""qwen2-vl-7b — VLM backbone, M-RoPE, GQA kv=4. [arXiv:2409.12191]

Per the assignment the ViT/SigLIP vision encoder + projector is a STUB:
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, patches, d_model). Only the language decoder is implemented.
"""
from repro.configs.base import ACT_SWIGLU, FrontendConfig, ModelConfig, register

QWEN2_VL_7B = register(ModelConfig(
    name="qwen2-vl-7b",
    kind="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,           # GQA kv=4
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation=ACT_SWIGLU,
    qkv_bias=True,            # qwen2 family uses QKV bias
    rope_theta=1_000_000.0,
    rope_type="mrope",        # multimodal rotary position embedding
    mrope_sections=(16, 24, 24),
    frontend=FrontendConfig(kind="vision", embed_dim=3584, tokens_per_item=256),
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="Qwen2-VL-7B [arXiv:2409.12191]; M-RoPE, dynamic-resolution ViT stubbed",
))
