"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer. [arXiv:2411.13676]

Hymba runs attention heads and SSM heads IN PARALLEL inside each block and
mixes their (normalized) outputs. Most layers use sliding-window attention
with a few global layers — which is what makes long_500k tractable.
"""
from repro.configs.base import (ACT_SWIGLU, ATTN_SLIDING, ModelConfig,
                                SSMConfig, register)

HYMBA_1P5B = register(ModelConfig(
    name="hymba-1.5b",
    kind="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,           # GQA kv=5
    head_dim=64,              # 1600 / 25
    d_ff=5504,
    vocab_size=32001,
    activation=ACT_SWIGLU,
    attn_type=ATTN_SLIDING,
    sliding_window=1024,      # hymba uses SWA in most layers
    global_attn_every=16,     # a few global-attention layers
    ssm=SSMConfig(
        state_dim=16,         # ssm_state=16 per assignment
        head_dim=50,          # d_inner=3200 -> 64 heads of 50
        expand=2,
        conv_dim=4,
        chunk_size=128,
        ngroups=1,
    ),
    hybrid_attn_ratio=0.5,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj",
                  "ssm_in_proj", "ssm_out_proj"),
    source="Hymba-1.5B [arXiv:2411.13676]; parallel attn+mamba heads, SWA+global",
))
