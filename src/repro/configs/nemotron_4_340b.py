"""nemotron-4-340b — dense, GQA kv=8, squared-ReLU FFN. [arXiv:2402.16819]"""
from repro.configs.base import ACT_RELU2, ModelConfig, register

NEMOTRON_4_340B = register(ModelConfig(
    name="nemotron-4-340b",
    kind="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,           # GQA kv=8
    head_dim=192,             # 18432 / 96
    d_ff=73728,
    vocab_size=256000,
    activation=ACT_RELU2,     # squared ReLU, non-gated
    rope_theta=10_000.0,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj", "up_proj", "down_proj"),
    source="Nemotron-4 340B [arXiv:2402.16819]; GQA kv=8, squared-ReLU",
))
