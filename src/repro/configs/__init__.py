"""Architecture + experiment configuration registry."""
from repro.configs.base import (ARCH_KINDS, INPUT_SHAPES, DECODE_32K,
                                FLConfig, FrontendConfig, LONG_500K,
                                LoRAConfig, MLAConfig, MoEConfig, ModelConfig,
                                PREFILL_32K, SSMConfig, ShapeConfig, TRAIN_4K,
                                get_config, list_configs, register)

# The ten architectures assigned to this paper from the public pool.
ASSIGNED_ARCHS = (
    "mamba2-1.3b",
    "nemotron-4-340b",
    "qwen2-vl-7b",
    "hymba-1.5b",
    "deepseek-v2-236b",
    "gemma-2b",
    "hubert-xlarge",
    "granite-3-8b",
    "llama4-maverick-400b-a17b",
    "qwen2-7b",
)

__all__ = [
    "ARCH_KINDS", "ASSIGNED_ARCHS", "INPUT_SHAPES", "DECODE_32K", "FLConfig",
    "FrontendConfig", "LONG_500K", "LoRAConfig", "MLAConfig", "MoEConfig",
    "ModelConfig", "PREFILL_32K", "SSMConfig", "ShapeConfig", "TRAIN_4K",
    "get_config", "list_configs", "register",
]
