"""mamba2-1.3b — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register

MAMBA2_1P3B = register(ModelConfig(
    name="mamba2-1.3b",
    kind="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,            # attention-free
    num_kv_heads=0,
    d_ff=0,                 # no separate FFN in mamba2 blocks
    vocab_size=50280,
    rope_type="none",
    attn_type="full",       # unused
    tie_embeddings=True,
    ssm=SSMConfig(
        state_dim=128,      # ssm_state=128 per assignment
        head_dim=64,
        expand=2,           # d_inner = 4096 -> 64 SSD heads
        conv_dim=4,
        chunk_size=256,
        ngroups=1,
    ),
    lora_targets=("ssm_in_proj", "ssm_out_proj"),
    source="SSD / Mamba-2 [arXiv:2405.21060]; state=128, d_model=2048, 48 layers",
))
