"""Configuration system for the raFLoRA reproduction framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``;
federated fine-tuning settings live in ``FLConfig``; LoRA adapter settings in
``LoRAConfig``; the four assigned input shapes in ``ShapeConfig``.

Configs are plain frozen dataclasses so they hash, compare, and serialize
cleanly, and so that jit caches key on them without surprises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Architecture kinds
# ---------------------------------------------------------------------------

ARCH_KINDS = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")

# Attention flavours -- selected per config.
ATTN_FULL = "full"          # causal full attention
ATTN_SLIDING = "sliding"    # sliding-window causal attention
ATTN_BIDIR = "bidirectional"  # encoder-only (hubert)

# Activation functions for the FFN.
ACT_GELU = "gelu"
ACT_GEGLU = "geglu"
ACT_SWIGLU = "swiglu"
ACT_RELU2 = "relu2"         # squared ReLU (nemotron)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (deepseek-v2, llama4-maverick)."""

    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    expert_d_ff: int = 0          # per-expert FFN hidden size
    shared_d_ff: int = 0          # shared-expert FFN hidden size
    router_aux_loss_coef: float = 0.001
    # llama4 interleaves dense and MoE layers; period=1 means every layer MoE.
    moe_layer_period: int = 1
    moe_layer_offset: int = 0

    def is_moe_layer(self, layer_idx: int) -> bool:
        return (layer_idx % self.moe_layer_period) == self.moe_layer_offset


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (deepseek-v2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD mixer settings."""

    state_dim: int = 128           # N: SSM state size per head
    num_heads: int = 0             # SSD heads (0 -> derived d_inner // head_dim)
    head_dim: int = 64             # P: channels per head
    expand: int = 2                # d_inner = expand * d_model
    conv_dim: int = 4              # short causal conv width
    chunk_size: int = 256          # SSD chunk length (dual form)
    ngroups: int = 1               # B/C groups (GVA-style)


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend (audio frames / vision patches).

    Per the assignment, the conv codec / ViT is NOT implemented; instead
    ``input_specs`` produces precomputed embeddings with these shapes.
    """

    kind: str = "none"             # "audio" | "vision" | "none"
    embed_dim: int = 0             # dimension of the precomputed embeddings
    tokens_per_item: int = 0       # e.g. patches per image (vlm interleave)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture from the assigned pool (or a paper-side model)."""

    name: str
    kind: str                       # one of ARCH_KINDS
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    activation: str = ACT_SWIGLU
    attn_type: str = ATTN_FULL
    sliding_window: int = 0         # 0 -> no window; used when attn_type=sliding
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "default"      # "default" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    rms_norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # sub-configs; None where not applicable
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # hybrid (hymba): parallel attention + ssm heads within one mixer
    hybrid_attn_ratio: float = 0.5  # fraction of mixer output from attention
    # which layers use full attention when attn_type == "sliding"
    # (hymba/llama4 keep a few global layers)
    global_attn_every: int = 0      # 0 -> none; n -> every n-th layer full attn
    # LoRA injection points (module names understood by models/lora_points.py)
    lora_targets: Tuple[str, ...] = ("q_proj", "v_proj")
    # citation for the config values
    source: str = ""

    # ---- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_encoder_only(self) -> bool:
        return self.attn_type == ATTN_BIDIR

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder_only

    def supports_long_context(self) -> bool:
        """True if decode at 500k context is sub-quadratic / bounded memory."""
        if self.kind in ("ssm", "hybrid"):
            return True
        # attention archs qualify via the sliding-window variant
        return self.sliding_window > 0

    def num_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        total += sum(self._params_per_layer(i) for i in range(L))
        total += d  # final norm
        return total

    def _params_per_layer(self, layer_idx: int = 0) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        p = 2 * d  # two rms norms
        # --- mixer ---
        if self.kind == "ssm":
            assert self.ssm is not None
            s = self.ssm
            d_in = s.expand * d
            nheads = s.num_heads or d_in // s.head_dim
            conv_ch = d_in + 2 * s.ngroups * s.state_dim
            p += d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)  # in_proj
            p += conv_ch * s.conv_dim  # conv
            p += nheads * 2            # A_log, D
            p += d_in * d              # out_proj
        elif self.mla is not None:
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            if m.q_lora_rank:
                p += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
            else:
                p += d * self.num_heads * qk_dim
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.num_heads * m.v_head_dim * d
        else:
            q_out = self.num_heads * hd
            kv_out = self.num_kv_heads * hd
            p += d * (q_out + 2 * kv_out) + q_out * d
            if self.kind == "hybrid":
                assert self.ssm is not None
                s = self.ssm
                d_in = s.expand * d
                nheads = s.num_heads or d_in // s.head_dim
                p += d * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
                p += (d_in + 2 * s.ngroups * s.state_dim) * s.conv_dim
                p += nheads * 2 + d_in * d
        # --- ffn ---
        if self.kind == "ssm":
            pass  # mamba2 has no separate FFN
        elif self.moe is not None and not self.moe.is_moe_layer(layer_idx):
            gated = self.activation in (ACT_GEGLU, ACT_SWIGLU)
            dense_ff = self.moe.expert_d_ff * 2 if self.moe.expert_d_ff else self.d_ff
            p += d * dense_ff * (3 if gated else 2)
        elif self.moe is not None:
            mo = self.moe
            e_ff = mo.expert_d_ff or self.d_ff
            gated = self.activation in (ACT_GEGLU, ACT_SWIGLU)
            per_expert = d * e_ff * (3 if gated else 2)
            p += mo.num_experts * per_expert
            p += mo.num_shared_experts * d * (mo.shared_d_ff or e_ff) * (3 if gated else 2)
            p += d * mo.num_experts  # router
        else:
            gated = self.activation in (ACT_GEGLU, ACT_SWIGLU)
            p += d * self.d_ff * (3 if gated else 2)
        return p

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab_size: int = 512, max_experts: int = 4) -> "ModelConfig":
        """A smoke-test variant of the same family (<=2 layers, d<=512)."""
        d_model = min(d_model, 512)
        scale = d_model / self.d_model
        num_heads = max(2, min(self.num_heads, 4))
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        while num_heads % num_kv:
            num_kv -= 1
        head_dim = max(16, d_model // num_heads)
        d_ff = max(32, int(self.d_ff * scale)) if self.d_ff else 0
        moe = None
        if self.moe is not None:
            n_e = min(self.moe.num_experts, max_experts)
            moe = dataclasses.replace(
                self.moe,
                num_experts=n_e,
                top_k=min(self.moe.top_k, n_e),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=max(32, int((self.moe.expert_d_ff or self.d_ff) * scale)),
                shared_d_ff=max(32, int((self.moe.shared_d_ff or self.d_ff) * scale)),
            )
        mla = None
        if self.mla is not None:
            mla = dataclasses.replace(
                self.mla, kv_lora_rank=64, q_lora_rank=0,
                qk_nope_head_dim=head_dim, qk_rope_head_dim=max(8, head_dim // 2),
                v_head_dim=head_dim)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16),
                head_dim=32, chunk_size=32)
        frontend = self.frontend
        if frontend.kind != "none":
            frontend = dataclasses.replace(
                frontend, embed_dim=d_model,
                tokens_per_item=min(frontend.tokens_per_item, 16) or 16)
        mrope_sections = self.mrope_sections
        if self.rope_type == "mrope":
            half = head_dim // 2
            s1 = max(1, half // 4)
            s2 = (half - s1) // 2
            s3 = half - s1 - s2
            mrope_sections = (s1, s2, s3)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=d_ff,
            vocab_size=vocab_size,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            mrope_sections=mrope_sections,
            moe=moe,
            mla=mla,
            ssm=ssm,
            frontend=frontend,
        )

    def with_sliding_window(self, window: int = 8192,
                            global_every: int = 0) -> "ModelConfig":
        """Sliding-window variant (enables long_500k for attention archs)."""
        return dataclasses.replace(
            self, name=self.name + "-swa", attn_type=ATTN_SLIDING,
            sliding_window=window, global_attn_every=global_every)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# LoRA + federated learning configs (paper settings as defaults)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LoRAConfig:
    """Heterogeneous-rank LoRA settings (paper Table 6-9 defaults)."""

    rank_levels: Tuple[int, ...] = (8, 16, 32, 48, 64)
    rank_probs: Tuple[float, ...] = (0.2, 0.2, 0.2, 0.2, 0.2)
    alpha_equals_rank: bool = True   # LoRA alpha = r_k -> unit scaling
    alpha: float = 0.0               # used when alpha_equals_rank=False
    dropout: float = 0.0
    init_b_zero: bool = True         # standard LoRA init: B=0, A ~ N(0, 1/r)
    # PEFT variant (paper Table 5): "lora" | "dora" | "qlora"
    variant: str = "lora"
    quant_bits: int = 4              # qlora fake-quant bits for the base

    @property
    def r_max(self) -> int:
        return max(self.rank_levels)

    @property
    def r_min(self) -> int:
        return min(self.rank_levels)

    def scaling(self, rank: int) -> float:
        if self.alpha_equals_rank:
            return 1.0
        return self.alpha / rank


@dataclass(frozen=True)
class FLConfig:
    """Federated fine-tuning settings (paper Section 6.1 defaults)."""

    num_clients: int = 100
    participation: float = 0.10      # fraction of clients per round
    num_rounds: int = 100
    local_epochs: int = 1
    local_batch_size: int = 32
    learning_rate: float = 5e-4
    lr_schedule: str = "linear"      # linear decay over rounds
    weight_decay: float = 0.0
    aggregator: str = "raflora"      # fedavg|hetlora|flora|flexlora|raflora
    seed: int = 0
    # data partitioning
    partition: str = "dirichlet"     # "iid" | "dirichlet" | "pathological"
    dirichlet_alpha: float = 1.0
    labels_per_client: int = 20      # for pathological c<labels>(alpha)

    @property
    def clients_per_round(self) -> int:
        return max(1, int(round(self.num_clients * self.participation)))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(config: ModelConfig) -> ModelConfig:
    if config.name in _REGISTRY:
        raise ValueError(f"duplicate architecture {config.name!r}")
    _REGISTRY[config.name] = config
    return config


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # import registers each architecture
    from repro.configs import (  # noqa: F401
        mamba2_1p3b, nemotron_4_340b, qwen2_vl_7b, hymba_1p5b,
        deepseek_v2_236b, gemma_2b, hubert_xlarge, granite_3_8b,
        llama4_maverick_400b_a17b, qwen2_7b, paper_models,
    )
    _LOADED = True
