"""The paper's own evaluation models, expressed in the same config system.

The paper fine-tunes ViT-base (vision), RoBERTa-base (text), and
LLaMA-3.2-3B / LLaMA-3.1-8B (reasoning). We register decoder/encoder
equivalents so the paper-side experiments run through the exact same
framework path as the assigned pool.
"""
from repro.configs.base import (ACT_GELU, ACT_SWIGLU, ATTN_BIDIR,
                                FrontendConfig, ModelConfig, register)

# ViT-base backbone (encoder; patch frontend stubbed like audio/vlm)
VIT_BASE = register(ModelConfig(
    name="vit-base",
    kind="vlm",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=100,            # CIFAR-100-like classifier head
    activation=ACT_GELU,
    attn_type=ATTN_BIDIR,
    rope_type="none",
    qkv_bias=True,
    frontend=FrontendConfig(kind="vision", embed_dim=768, tokens_per_item=197),
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj", "up_proj", "down_proj"),
    source="ViT-B/16 [arXiv:2010.11929]; paper's vision model",
))

# RoBERTa-base (encoder-only)
ROBERTA_BASE = register(ModelConfig(
    name="roberta-base",
    kind="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50265,
    activation=ACT_GELU,
    attn_type=ATTN_BIDIR,
    rope_type="none",
    qkv_bias=True,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj", "up_proj", "down_proj"),
    source="RoBERTa-base [arXiv:1907.11692]; paper's NLU model",
))

# LLaMA-3.2-3B
LLAMA32_3B = register(ModelConfig(
    name="llama3.2-3b",
    kind="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    activation=ACT_SWIGLU,
    rope_theta=500_000.0,
    tie_embeddings=True,
    lora_targets=("q_proj", "v_proj"),   # paper: LoRA on Q,V for reasoning
    source="LLaMA-3.2-3B [meta llama3.2]; paper's 3B reasoning model",
))

# LLaMA-3.1-8B
LLAMA31_8B = register(ModelConfig(
    name="llama3.1-8b",
    kind="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    activation=ACT_SWIGLU,
    rope_theta=500_000.0,
    lora_targets=("q_proj", "v_proj"),
    source="LLaMA-3.1-8B [arXiv:2407.21783]; paper's 8B reasoning model",
))
