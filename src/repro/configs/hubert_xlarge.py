"""hubert-xlarge — audio encoder-only transformer (w2v2 arch). [arXiv:2106.07447]

Encoder-only: no decode step exists; decode_32k / long_500k are skipped per
spec (noted in DESIGN.md). The mel-spectrogram + conv feature extractor is a
STUB — ``input_specs()`` supplies precomputed frame embeddings.
"""
from repro.configs.base import (ACT_GELU, ATTN_BIDIR, FrontendConfig,
                                ModelConfig, register)

HUBERT_XLARGE = register(ModelConfig(
    name="hubert-xlarge",
    kind="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,           # full MHA
    head_dim=80,
    d_ff=5120,
    vocab_size=504,            # k-means target codebook
    activation=ACT_GELU,
    attn_type=ATTN_BIDIR,      # encoder-only
    rope_type="none",          # learned/conv positions in the stubbed frontend
    qkv_bias=True,
    frontend=FrontendConfig(kind="audio", embed_dim=1280, tokens_per_item=0),
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="HuBERT X-Large [arXiv:2106.07447]; encoder-only, conv codec stubbed",
))
