"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Llama-4 interleaves dense and MoE FFN layers and uses chunked/sliding
attention on most layers for long context; we model the latter with the
sliding-window variant (window=8192) for the long_500k shape.
"""
from repro.configs.base import (ACT_SWIGLU, FrontendConfig, MoEConfig,
                                ModelConfig, register)

LLAMA4_MAVERICK = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    kind="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,            # GQA kv=8
    head_dim=128,
    d_ff=8192,                 # expert intermediate size
    vocab_size=202048,
    activation=ACT_SWIGLU,
    rope_theta=500_000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,               # top-1 routing
        num_shared_experts=1,  # llama4 keeps one shared expert
        expert_d_ff=8192,
        shared_d_ff=8192,
        moe_layer_period=2,    # interleaved dense/MoE layers
        moe_layer_offset=1,
    ),
    # early fusion: image patches enter the token stream directly
    frontend=FrontendConfig(kind="vision", embed_dim=5120, tokens_per_item=144),
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="Llama-4 Maverick [hf:meta-llama/Llama-4-Scout-17B-16E]; MoE top-1, early fusion",
))
