"""deepseek-v2-236b — MoE 160e top-6 with 2 shared experts, MLA kv_lora=512.
[arXiv:2405.04434]
"""
from repro.configs.base import (ACT_SWIGLU, MLAConfig, MoEConfig, ModelConfig,
                                register)

DEEPSEEK_V2_236B = register(ModelConfig(
    name="deepseek-v2-236b",
    kind="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: kv heads == q heads (latent-compressed)
    head_dim=128,
    d_ff=1536,                 # routed-expert intermediate size
    vocab_size=102400,
    activation=ACT_SWIGLU,
    rope_theta=10_000.0,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        num_shared_experts=2,
        expert_d_ff=1536,
        shared_d_ff=1536,
        router_aux_loss_coef=0.001,
    ),
    lora_targets=("q_a_proj", "kv_a_proj", "o_proj"),
    source="DeepSeek-V2 [arXiv:2405.04434]; MLA kv_lora=512, 2 shared + 160 routed top-6",
))
