"""granite-3-8b — dense, GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ACT_SWIGLU, ModelConfig, register

GRANITE_3_8B = register(ModelConfig(
    name="granite-3-8b",
    kind="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,            # GQA kv=8
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    activation=ACT_SWIGLU,
    rope_theta=10_000.0,
    tie_embeddings=True,
    lora_targets=("q_proj", "k_proj", "v_proj", "o_proj"),
    source="Granite-3.0-8B [hf:ibm-granite/granite-3.0-2b-base]; GQA",
))
