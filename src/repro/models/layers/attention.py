"""Attention: blockwise (flash-style) softmax attention in pure JAX.

TPU adaptation notes (DESIGN.md §4): rather than materializing (Lq, Lkv)
score matrices -- which at prefill_32k would be terabytes -- we stream KV
blocks through an online-softmax ``lax.scan``, the standard TPU formulation
(compute lives in MXU matmuls; running max/denominator live in VREGs). The
same code path serves:

  * full causal attention          (train / prefill)
  * sliding-window causal          (long-context variants, hymba, llama4)
  * bidirectional                  (hubert, vit, roberta encoders)
  * single-token decode            (serve_step; q length 1 vs KV cache)

GQA/MQA is handled by grouping query heads over shared KV heads.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_group(q: jnp.ndarray, num_kv_heads: int) -> jnp.ndarray:
    """(B, L, H, D) -> (B, L, KVH, G, D) with G = H // KVH."""
    b, l, h, d = q.shape
    return q.reshape(b, l, num_kv_heads, h // num_kv_heads, d)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, sliding_window=0,
                        q_offset: int = 0,
                        block_q: int = 1024, block_kv: int = 1024,
                        softcap: float = 0.0,
                        bf16_scores: bool = False) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Lq, H, D); k, v: (B, Lkv, KVH, D). Returns (B, Lq, H, D).
    ``q_offset`` is the absolute position of q[0] (prefill continuation /
    decode). ``sliding_window``: 0/None = unlimited; may be a traced scalar
    (per-layer global-vs-window selection under lax.scan).
    """
    use_window = sliding_window is not None and not (
        isinstance(sliding_window, int) and sliding_window == 0)
    b, lq, h, d = q.shape
    _, lkv, kvh, _ = k.shape
    scale = d ** -0.5

    block_q = min(block_q, lq)
    block_kv = min(block_kv, lkv)
    # pad to block multiples
    pad_q = (-lq) % block_q
    pad_kv = (-lkv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = (lq + pad_q) // block_q
    nkv = (lkv + pad_kv) // block_kv

    qg = _gqa_group(q, kvh)                      # (B, Lq, KVH, G, D)
    g = qg.shape[3]
    qg = qg.reshape(b, nq, block_q, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KVH, G, bq, D)
    kb = k.reshape(b, nkv, block_kv, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nkv, block_kv, kvh, d).transpose(1, 0, 3, 2, 4)
    # (nkv, B, KVH, bkv, D)

    q_pos_base = jnp.arange(nq) * block_q        # per q block
    kv_pos_base = jnp.arange(nkv) * block_kv

    def q_block_body(_, qi):
        q_blk, q_idx = qi                        # (B, KVH, G, bq, D), scalar
        q_pos = q_offset + q_idx + jnp.arange(block_q)  # absolute positions

        def kv_block_body(carry, kvi):
            acc, m, denom = carry
            k_blk, v_blk, kv_idx = kvi
            kv_pos = kv_idx + jnp.arange(block_kv)
            # inputs stay bf16 (collectives/copies move half the bytes);
            # the MXU accumulates in f32 via preferred_element_type.
            # bf16_scores: emit the dot in bf16 so its VJP dots are bf16
            # too -- an f32 dot here poisons every backward collective
            # upstream (§Perf; the Pallas kernel is the lossless fix).
            if bf16_scores:
                s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk,
                               k_blk).astype(jnp.float32) * scale
            else:
                s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk,
                               preferred_element_type=jnp.float32) * scale
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            mask = jnp.ones((block_q, block_kv), dtype=bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if use_window:
                mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
            # mask out kv padding
            mask &= (kv_pos < lkv)[None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            denom = denom * alpha + p.sum(axis=-1)
            # p in the compute dtype for the MXU; f32 accumulator
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((b, kvh, g, block_q, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, block_q), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, kvh, g, block_q), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_block_body, (acc0, m0, d0), (kb, vb, kv_pos_base))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_block_body, None, (qg, q_pos_base))
    # out: (nq, B, KVH, G, bq, D) -> (B, Lq, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * block_q, h, d)
    return out[:, :lq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len,
                     *, softcap: float = 0.0) -> jnp.ndarray:
    """Single-token decode: q (B, 1, H, D) vs cache (B, S, KVH, D).

    ``cache_len`` (scalar or (B,)) masks cache positions >= len.
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    scale = d ** -0.5
    qg = _gqa_group(q, kvh)[:, 0]                # (B, KVH, G, D)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(qg.dtype),
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(jnp.asarray(cache_len), (-1, 1))  # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
