"""Dense projection with optional heterogeneous-rank LoRA adapter.

This is the layer the paper's technique attaches to. A LoRA-augmented dense
layer carries server-side factors sized at ``r_max``; a client with rank
``r_k`` receives a statically-truncated slice (the broadcast step of
Algorithm 1 line 4) and its update flows back through the aggregators in
``repro.core.aggregation``.

Parameter layout per dense layer::

    {"w": (in, out) [, "b": (out,)]
     [, "lora_a": (r, in), "lora_b": (out, r)]}

LoRA forward (scaling s = alpha/r, s=1 under the paper's alpha=r setting)::

    y = x @ w + s * (x @ a.T) @ b.T

The fused Pallas path (kernels/lora_apply) computes the same expression with
MXU-aligned tiling; the jnp expression here is the oracle and the CPU path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               lora_rank: int = 0, dtype=jnp.float32,
               init_scale: Optional[float] = None) -> dict:
    """Initialize a dense layer, optionally with LoRA factors of rank r_max."""
    k_w, k_a = jax.random.split(key)
    scale = init_scale if init_scale is not None else d_in ** -0.5
    params = {"w": (jax.random.normal(k_w, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype=dtype)
    if lora_rank > 0:
        params.update(lora_init(k_a, d_in, d_out, lora_rank, dtype=dtype))
    return params


def lora_init(key, d_in: int, d_out: int, rank: int, dtype=jnp.float32) -> dict:
    """Standard LoRA init: A ~ N(0, 1/r), B = 0 so the adapter starts at 0."""
    a = jax.random.normal(key, (rank, d_in)) * (1.0 / rank) ** 0.5
    return {"lora_a": a.astype(dtype),
            "lora_b": jnp.zeros((d_out, rank), dtype=dtype)}


def dense_apply(params: dict, x: jnp.ndarray, *, lora_rank: int = -1,
                lora_scale: float = 1.0,
                use_kernel: bool = False) -> jnp.ndarray:
    """Apply dense + optional LoRA (or DoRA when a magnitude is present).

    lora_rank: -1 -> use full factors if present; 0 -> disable adapter;
    r > 0 -> statically truncate factors to the client rank r.

    Per-request (multi-tenant serving) adapters: when the LoRA leaves carry
    a leading batch axis matching x -- lora_a (B, r, in), lora_b (B, out, r)
    with x (B, L, in), the substitution layout ``serving/engine`` builds --
    each batch row applies its own factors. ``use_kernel`` routes that
    branch through the paged Pallas kernel (kernels/ops.batched_lora_apply);
    off, it runs the batched-einsum oracle path.
    """
    if lora_rank != 0 and "lora_m" in params and "lora_a" in params:
        return _dora_apply(params, x, lora_rank=lora_rank,
                           lora_scale=lora_scale)
    if (lora_rank != 0 and "lora_a" in params
            and params["lora_a"].ndim == 3 and x.ndim == 3
            and params["lora_a"].shape[0] == x.shape[0]):
        return _batched_lora_dense(params, x, lora_scale, use_kernel)
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    if lora_rank != 0 and "lora_a" in params:
        a = params["lora_a"]
        b = params["lora_b"]
        if lora_rank > 0:
            a = a[:lora_rank, :]
            b = b[:, :lora_rank]
        # low-rank bottleneck in the params' (higher) precision, cast at ends
        z = x @ a.astype(x.dtype).T
        y = y + lora_scale * (z @ b.astype(x.dtype).T)
    return y


def _batched_lora_dense(params: dict, x: jnp.ndarray, lora_scale: float,
                        use_kernel: bool) -> jnp.ndarray:
    """Per-request adapters: x (B, L, in); lora_a (B, r, in);
    lora_b (B, out, r). Rank heterogeneity arrives as omega-style zero
    columns beyond each request's true rank (AdapterStore packing), so no
    per-row truncation is needed -- zero columns are inert."""
    a = params["lora_a"]
    b_f = params["lora_b"]
    if use_kernel:
        from repro.kernels.ops import batched_lora_apply
        bsz, l, _ = x.shape
        scales = jnp.broadcast_to(
            jnp.asarray(lora_scale, jnp.float32), (bsz,))
        ids = jnp.broadcast_to(
            jnp.arange(bsz, dtype=jnp.int32)[:, None], (bsz, l))
        y = batched_lora_apply(x, params["w"].astype(x.dtype), a, b_f,
                               scales, ids)
    else:
        y = x @ params["w"].astype(x.dtype)
        z = jnp.einsum("bld,brd->blr", x, a.astype(x.dtype))
        y = y + lora_scale * jnp.einsum("blr,bor->blo", z,
                                        b_f.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def _dora_apply(params: dict, x: jnp.ndarray, *, lora_rank: int,
                lora_scale: float) -> jnp.ndarray:
    """DoRA (arXiv:2402.09353): weight-decomposed adaptation.

        W' = m * (W + s*dW) / ||W + s*dW||_col,  dW = A^T B^T (in, out)

    The trainable magnitude ``lora_m`` (out,) travels with the adapters in
    federated aggregation (FedAvg'd; it is not rank-structured). Used by the
    paper's Table 5 extension -- FlexLoRA-DoRA degrades under rank collapse
    because magnitude reweighting cannot recover attenuated directions.
    """
    a = params["lora_a"]
    b = params["lora_b"]
    if lora_rank > 0:
        a = a[:lora_rank, :]
        b = b[:, :lora_rank]
    w = params["w"].astype(jnp.float32)
    dw = a.astype(jnp.float32).T @ b.astype(jnp.float32).T     # (in, out)
    adapted = w + lora_scale * dw
    col_norm = jnp.sqrt(jnp.sum(jnp.square(adapted), axis=0) + 1e-8)
    scaled = adapted * (params["lora_m"].astype(jnp.float32) / col_norm)[None]
    y = x @ scaled.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def dora_magnitude_init(w: jnp.ndarray) -> jnp.ndarray:
    """DoRA init: m = column norms of the pretrained weight.

    Handles layer-stacked weights (..., in, out): norm over the in dim.
    """
    return jnp.sqrt(jnp.sum(jnp.square(w.astype(jnp.float32)), axis=-2))


def quantize_dequantize(w: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """QLoRA simulation: per-output-channel symmetric fake quantization of
    the frozen base weight. The adapter math is unchanged (as in QLoRA);
    what the federated experiment tests is aggregation robustness to a
    quantized base (paper Table 5)."""
    levels = 2 ** (bits - 1) - 1
    # per-output-channel over the IN dim (handles layer-stacked weights)
    scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / levels
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(w / scale)
    q = jnp.clip(q, -levels - 1, levels)
    return (q * scale).astype(w.dtype)


def stacked_dense_init(key, num_layers: int, d_in: int, d_out: int,
                       **kw) -> dict:
    """Per-layer params stacked on a leading axis (for lax.scan blocks)."""
    keys = jax.random.split(key, num_layers)
    layers = [dense_init(k, d_in, d_out, **kw) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
