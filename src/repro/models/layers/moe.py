"""Mixture-of-experts FFN with dropless sort + ragged_dot dispatch.

TPU adaptation (DESIGN.md §4): instead of a capacity-factor one-hot dispatch
tensor (O(tokens x E x C) memory -- infeasible at deepseek-v2 scale), tokens
are sorted by assigned expert and processed with ``jax.lax.ragged_dot``,
whose TPU lowering is a grouped MXU matmul. Two sharding strategies:

  * "tp"  (default): expert weights sharded on the FFN dim over the `model`
    axis -- no all-to-all, tokens stay put; good when E*d_ff is modest.
  * "ep": expert-parallel via shard_map -- experts sharded over `model`,
    tokens all-gathered per shard, local ragged compute, psum_scatter
    combine. Exercised by the perf-iteration harness.

Router: softmax top-k with optional shared experts (deepseek-v2) and an
aux load-balance loss (Switch-style), returned for logging/training.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers.dense import dense_init
from repro.models.layers.mlp import _act, is_gated, mlp_apply, mlp_init

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


def moe_init(key, d_model: int, cfg: MoEConfig, activation: str, *,
             lora_ranks: dict, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    e, ff = cfg.num_experts, cfg.expert_d_ff
    gated = is_gated(activation)
    scale = d_model ** -0.5
    def w(k, shape):
        return (jax.random.normal(k, shape) * scale).astype(dtype)
    params = {
        "router": dense_init(ks[0], d_model, e, dtype=jnp.float32),
        # expert weights stacked on a leading expert axis
        "w_up": w(ks[1], (e, d_model, ff)),
        "w_down": w(ks[2], (e, ff, d_model)),
    }
    if gated:
        params["w_gate"] = w(ks[3], (e, d_model, ff))
    if cfg.num_shared_experts:
        shared_ff = (cfg.shared_d_ff or ff) * cfg.num_shared_experts
        params["shared"] = mlp_init(
            jax.random.fold_in(key, 7), d_model, shared_ff, activation,
            lora_ranks={}, dtype=dtype)
    return params


def router_topk(router_logits: jnp.ndarray, top_k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(T, E) logits -> (weights (T,k), experts (T,k), aux_loss scalar)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    e = router_logits.shape[-1]
    fraction = jnp.mean(
        jax.nn.one_hot(experts, e, dtype=jnp.float32).sum(axis=1), axis=0)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(fraction * mean_prob)
    return weights, experts, aux


def _expert_ffn_sorted(tokens_rep: jnp.ndarray, group_sizes: jnp.ndarray,
                       params: dict, activation: str) -> jnp.ndarray:
    """ragged grouped FFN: tokens_rep (Tk, d) sorted by expert."""
    up = jax.lax.ragged_dot(tokens_rep, params["w_up"].astype(tokens_rep.dtype),
                            group_sizes)
    if "w_gate" in params:
        gate = jax.lax.ragged_dot(
            tokens_rep, params["w_gate"].astype(tokens_rep.dtype), group_sizes)
        h = _act(activation, gate) * up
    else:
        h = _act(activation, up)
    return jax.lax.ragged_dot(h, params["w_down"].astype(tokens_rep.dtype),
                              group_sizes)


def _expert_ffn_capacity(sorted_tokens: jnp.ndarray,
                         group_sizes: jnp.ndarray, params: dict,
                         activation: str, capacity: int) -> jnp.ndarray:
    """Capacity-bounded grouped FFN (§Perf iteration A).

    ragged_dot's portable lowering is a DENSE dot over all groups -- every
    token visits every local expert (E_local x waste). Since tokens are
    already SORTED by expert, each expert's tokens are contiguous: slice a
    fixed-capacity window per expert, run a batched (E, C, d) x (E, d, f)
    matmul (true grouped MXU work), mask rows beyond the group size, and
    scatter-add back. Tokens beyond capacity are dropped (standard capacity
    factor); compute = E x C x d x f ~= capacity_factor x ideal.
    """
    tk, d = sorted_tokens.shape
    e = group_sizes.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes          # (E,)
    offs = jnp.arange(capacity)
    idx = starts[:, None] + offs[None, :]                   # (E, C)
    valid = offs[None, :] < group_sizes[:, None]            # (E, C)
    idx_c = jnp.minimum(idx, tk - 1)
    toks = sorted_tokens[idx_c] * valid[..., None].astype(sorted_tokens.dtype)
    up = jnp.einsum("ecd,edf->ecf", toks,
                    params["w_up"].astype(toks.dtype))
    if "w_gate" in params:
        gate = jnp.einsum("ecd,edf->ecf", toks,
                          params["w_gate"].astype(toks.dtype))
        h = _act(activation, gate) * up
    else:
        h = _act(activation, up)
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(toks.dtype))
    out = out * valid[..., None].astype(out.dtype)
    return jnp.zeros((tk, d), out.dtype).at[idx_c.reshape(-1)].add(
        out.reshape(-1, d))


def moe_apply_ep(params: dict, x: jnp.ndarray, cfg: MoEConfig,
                 activation: str, mesh, ep_axis: str = "model", *,
                 batch_axes=("data",), lora_rank: int = -1,
                 lora_scale: float = 1.0,
                 capacity_factor: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map (DESIGN.md §5).

    Experts are sharded over ``ep_axis``; each device routes its local batch
    shard's tokens, computes ONLY its local experts' contributions with
    ragged_dot, and a psum over ``ep_axis`` combines per-token outputs --
    the TPU-native analogue of the all-to-all dispatch.
    """
    from jax.sharding import PartitionSpec as P

    e = cfg.num_experts
    axis_size = mesh.shape[ep_axis]
    local_e = e // axis_size
    orig_shape = x.shape

    def block(xt, router_w, w_up, w_gate, w_down):
        # xt: (b_loc, l, d) local batch shard; expert weights local slice
        d = xt.shape[-1]
        toks = xt.reshape(-1, d)
        t = toks.shape[0]
        logits = toks.astype(jnp.float32) @ router_w           # (T, E) full
        weights, experts, aux = router_topk(logits, cfg.top_k)
        my_idx = jax.lax.axis_index(ep_axis)
        e_lo = my_idx * local_e
        flat_expert = experts.reshape(-1)
        flat_weight = weights.reshape(-1)
        token_idx = jnp.repeat(jnp.arange(t), cfg.top_k)
        local = (flat_expert >= e_lo) & (flat_expert < e_lo + local_e)
        # map non-local assignments to a dummy trailing group with 0 weight
        local_expert = jnp.where(local, flat_expert - e_lo, local_e)
        w_masked = jnp.where(local, flat_weight, 0.0)
        order = jnp.argsort(local_expert, stable=True)
        sorted_tokens = toks[token_idx[order]]
        group_sizes = jnp.bincount(local_expert, length=local_e + 1)
        p_local = {"w_up": jnp.concatenate(
                       [w_up, jnp.zeros_like(w_up[:1])], axis=0),
                   "w_down": jnp.concatenate(
                       [w_down, jnp.zeros_like(w_down[:1])], axis=0)}
        if w_gate is not None:
            p_local["w_gate"] = jnp.concatenate(
                [w_gate, jnp.zeros_like(w_gate[:1])], axis=0)
        if capacity_factor > 0:
            # expected tokens per local expert = T*k/E (global balance);
            # dummy group (overflow of non-local tokens) gets capacity too
            cap = int(capacity_factor * (t * cfg.top_k) / e) + 1
            out_sorted = _expert_ffn_capacity(sorted_tokens, group_sizes,
                                              p_local, activation, cap)
        else:
            out_sorted = _expert_ffn_sorted(sorted_tokens, group_sizes,
                                            p_local, activation)
        contrib = out_sorted * w_masked[order][:, None].astype(out_sorted.dtype)
        combined = jnp.zeros((t, d), out_sorted.dtype).at[
            token_idx[order]].add(contrib)
        combined = jax.lax.psum(combined, ep_axis)
        return combined.reshape(xt.shape), aux

    bspec = P(batch_axes, None, None)
    out, aux = _shard_map(
        block, mesh=mesh,
        in_specs=(bspec, P(), P(ep_axis, None, None),
                  P(ep_axis, None, None) if "w_gate" in params else P(),
                  P(ep_axis, None, None)),
        out_specs=(bspec, P()),
        **_SHARD_MAP_KW,
    )(x, params["router"]["w"],
      params["w_up"], params.get("w_gate", jnp.zeros((0,))), params["w_down"])
    if "shared" in params:
        out = out + mlp_apply(params["shared"], x, activation, lora_rank=0)
    return out.reshape(orig_shape), aux


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig, activation: str,
              *, lora_rank: int = -1, lora_scale: float = 1.0
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. x (..., d). Returns (out, aux_loss)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)                                      # (T, d)
    t = xt.shape[0]
    logits = xt.astype(jnp.float32) @ params["router"]["w"]    # (T, E)
    weights, experts, aux = router_topk(logits, cfg.top_k)     # (T,k)

    # replicate tokens k times, sort by expert id
    tk = t * cfg.top_k
    flat_expert = experts.reshape(tk)                          # (Tk,)
    flat_weight = weights.reshape(tk)
    token_idx = jnp.repeat(jnp.arange(t), cfg.top_k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_tokens = xt[token_idx[order]]                       # (Tk, d)
    group_sizes = jnp.bincount(flat_expert, length=cfg.num_experts)
    out_sorted = _expert_ffn_sorted(sorted_tokens, group_sizes, params,
                                    activation)
    # unsort + weighted combine back to tokens
    contrib = out_sorted * flat_weight[order][:, None].astype(out_sorted.dtype)
    combined = jnp.zeros((t, d), out_sorted.dtype).at[token_idx[order]].add(contrib)
    if "shared" in params:
        combined = combined + mlp_apply(params["shared"], xt, activation,
                                        lora_rank=0)
    return combined.reshape(orig_shape), aux
