"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed to a shared latent ``c_kv`` of dimension
``kv_lora_rank`` plus a small decoupled RoPE key; at decode time the cache
stores ONLY (c_kv, k_rope) -- (512 + 64) floats/token for deepseek-v2 --
instead of per-head K/V, which is why MLA survives decode_32k x batch 128
and (with sliding window) long_500k.

Train/prefill use the "naive" expansion (materialize per-head K/V from the
latent); decode uses the compressed cache with per-step up-projection.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers.attention import blockwise_attention, decode_attention
from repro.models.layers.dense import dense_apply, dense_init
from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.models.layers.rope import apply_rope


def mla_init(key, d_model: int, num_heads: int, cfg: MLAConfig, *,
             lora_ranks: dict, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    params = {}
    if cfg.q_lora_rank:
        params["q_a"] = dense_init(ks[0], d_model, cfg.q_lora_rank, dtype=dtype,
                                   lora_rank=lora_ranks.get("q_a_proj", 0))
        params["q_a_norm"] = rms_norm_init(cfg.q_lora_rank, dtype=dtype)
        params["q_b"] = dense_init(ks[1], cfg.q_lora_rank,
                                   num_heads * qk_head, dtype=dtype)
    else:
        params["q"] = dense_init(ks[0], d_model, num_heads * qk_head,
                                 dtype=dtype, lora_rank=lora_ranks.get("q_a_proj", 0))
    # joint KV compression + decoupled rope key
    params["kv_a"] = dense_init(
        ks[2], d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype=dtype,
        lora_rank=lora_ranks.get("kv_a_proj", 0))
    params["kv_a_norm"] = rms_norm_init(cfg.kv_lora_rank, dtype=dtype)
    params["kv_b"] = dense_init(
        ks[3], cfg.kv_lora_rank,
        num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype=dtype)
    params["o"] = dense_init(ks[4], num_heads * cfg.v_head_dim, d_model,
                             dtype=dtype, lora_rank=lora_ranks.get("o_proj", 0))
    return params


def _project_q(params, x, num_heads, cfg: MLAConfig, lk):
    b_, l = x.shape[:2]
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if "q_a" in params:
        qa = rms_norm(params["q_a_norm"], dense_apply(params["q_a"], x, **lk))
        q = dense_apply(params["q_b"], qa)
    else:
        q = dense_apply(params["q"], x, **lk)
    q = q.reshape(b_, l, num_heads, qk_head)
    return jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)  # nope, rope parts


def _latent_kv(params, x, cfg: MLAConfig, lk):
    kv = dense_apply(params["kv_a"], x, **lk)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(params["kv_a_norm"], c_kv)
    return c_kv, k_rope  # (B, L, R), (B, L, rope_dim)


def _expand_kv(params, c_kv, num_heads, cfg: MLAConfig):
    b_, l = c_kv.shape[:2]
    kvb = dense_apply(params["kv_b"], c_kv)
    kvb = kvb.reshape(b_, l, num_heads, cfg.qk_nope_head_dim + cfg.v_head_dim)
    return jnp.split(kvb, [cfg.qk_nope_head_dim], axis=-1)  # k_nope, v


def mla_attention(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                  num_heads: int, cfg: MLAConfig, *, rope_theta: float,
                  causal: bool = True, sliding_window: int = 0,
                  lora_rank: int = -1, lora_scale: float = 1.0,
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence MLA. Returns (out, (c_kv, k_rope)) for cache fill."""
    lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
    b_, l = x.shape[:2]
    q_nope, q_rope = _project_q(params, x, num_heads, cfg, lk)
    c_kv, k_rope = _latent_kv(params, x, cfg, lk)
    k_nope, v = _expand_kv(params, c_kv, num_heads, cfg)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_rope_r = apply_rope(k_rope[:, :, None, :], positions, rope_theta)
    k_rope_b = jnp.broadcast_to(
        k_rope_r, (b_, l, num_heads, cfg.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v to qk head dim so one attention call serves both (standard trick)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - cfg.v_head_dim)))
    out = blockwise_attention(q, k, v_pad, causal=causal,
                              sliding_window=sliding_window)
    out = out[..., :cfg.v_head_dim].reshape(b_, l, num_heads * cfg.v_head_dim)
    return dense_apply(params["o"], out, **lk), (c_kv, apply_rope(
        k_rope[:, :, None, :], positions, rope_theta)[:, :, 0, :])


def mla_decode(params: dict, x: jnp.ndarray, position: jnp.ndarray,
               cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
               cache_len, num_heads: int, cfg: MLAConfig, *,
               rope_theta: float, lora_rank: int = -1,
               lora_scale: float = 1.0,
               write_idx=None) -> Tuple[jnp.ndarray, Tuple]:
    """One-token MLA decode against the compressed cache.

    x (B, 1, d); cache_ckv (B, S, R); cache_krope (B, S, rope_dim);
    position (B,) absolute position of the new token.
    """
    lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
    b_ = x.shape[0]
    q_nope, q_rope = _project_q(params, x, num_heads, cfg, lk)   # (B,1,H,*)
    c_kv_new, k_rope_new = _latent_kv(params, x, cfg, lk)        # (B,1,*)
    pos2d = position[:, None]
    q_rope = apply_rope(q_rope, pos2d, rope_theta)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], pos2d, rope_theta)[:, :, 0]
    # write the new latent into the cache: scalar index (single-sequence
    # decode; ring index when the cache is window-sized) or per-slot (B,)
    # indices (continuous-batching serving with ragged slot lengths) --
    # mirroring the per-head attention path in transformer._attn_decode
    wi = jnp.asarray(cache_len if write_idx is None else write_idx)
    if jnp.ndim(wi) == 0:
        cache_ckv = jax.lax.dynamic_update_slice(
            cache_ckv, c_kv_new.astype(cache_ckv.dtype), (0, wi, 0))
        cache_krope = jax.lax.dynamic_update_slice(
            cache_krope, k_rope_new.astype(cache_krope.dtype), (0, wi, 0))
    else:
        rows = jnp.arange(b_)
        cache_ckv = cache_ckv.at[rows, wi].set(
            c_kv_new[:, 0].astype(cache_ckv.dtype))
        cache_krope = cache_krope.at[rows, wi].set(
            k_rope_new[:, 0].astype(cache_krope.dtype))
    # absorbed attention: expand latent to per-head K/V for scoring.
    k_nope_c, v_c = _expand_kv(params, cache_ckv, num_heads, cfg)  # (B,S,H,*)
    k_rope_b = jnp.broadcast_to(
        cache_krope[:, :, None, :],
        cache_krope.shape[:2] + (num_heads, cfg.qk_rope_head_dim))
    k = jnp.concatenate([k_nope_c, k_rope_b], axis=-1)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    v_pad = jnp.pad(v_c, ((0, 0), (0, 0), (0, 0),
                          (0, qk_head - cfg.v_head_dim)))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)               # (B,1,H,Dqk)
    out = decode_attention(q, k, v_pad, jnp.asarray(cache_len) + 1)
    out = out[..., :cfg.v_head_dim].reshape(b_, 1, num_heads * cfg.v_head_dim)
    return dense_apply(params["o"], out, **lk), (cache_ckv, cache_krope)
