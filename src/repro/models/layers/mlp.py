"""Feed-forward blocks: gated (SwiGLU/GeGLU) and non-gated (GELU/ReLU²)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ACT_GEGLU, ACT_GELU, ACT_RELU2, ACT_SWIGLU
from repro.models.layers.dense import dense_apply, dense_init


def is_gated(activation: str) -> bool:
    return activation in (ACT_GEGLU, ACT_SWIGLU)


def _act(activation: str, x: jnp.ndarray) -> jnp.ndarray:
    if activation == ACT_GELU:
        return jax.nn.gelu(x)
    if activation == ACT_GEGLU:
        return jax.nn.gelu(x)
    if activation == ACT_SWIGLU:
        return jax.nn.silu(x)
    if activation == ACT_RELU2:
        r = jax.nn.relu(x)
        return r * r          # squared ReLU (nemotron-4)
    raise ValueError(f"unknown activation {activation!r}")


def mlp_init(key, d_model: int, d_ff: int, activation: str, *,
             lora_ranks: dict, dtype=jnp.float32) -> dict:
    """lora_ranks maps {"up_proj": r, "gate_proj": r, "down_proj": r} (0=off)."""
    ks = jax.random.split(key, 3)
    params = {
        "up": dense_init(ks[0], d_model, d_ff, dtype=dtype,
                         lora_rank=lora_ranks.get("up_proj", 0)),
        "down": dense_init(ks[1], d_ff, d_model, dtype=dtype,
                           lora_rank=lora_ranks.get("down_proj", 0)),
    }
    if is_gated(activation):
        params["gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype,
                                    lora_rank=lora_ranks.get("gate_proj", 0))
    return params


def mlp_apply(params: dict, x: jnp.ndarray, activation: str, *,
              lora_rank: int = -1, lora_scale: float = 1.0) -> jnp.ndarray:
    lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
    up = dense_apply(params["up"], x, **lk)
    if "gate" in params:
        gate = _act(activation, dense_apply(params["gate"], x, **lk))
        h = gate * up
    else:
        h = _act(activation, up)
    return dense_apply(params["down"], h, **lk)
