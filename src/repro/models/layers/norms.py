"""Normalization layers (functional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm: statistics in f32, MULTIPLY in the input dtype.

    Keeping the (B, L, D)-sized products in bf16 matters under sharding:
    if the normalized tensor is f32, the per-layer residual all-gathers of
    a TP mesh move twice the bytes (§Perf iteration, measured on qwen2).
    The f32 part is only the (B, L, 1) variance reduction.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def layer_norm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layer_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    normed = (xf - mean) * (var + eps) ** -0.5
    out = normed * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)
