"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

TPU adaptation (DESIGN.md §4): we use the *chunked dual form* -- intra-chunk
terms are (Q x Q) matmuls that feed the MXU, and the inter-chunk recurrence
is a short ``lax.scan`` over chunk states -- instead of the GPU-style
parallel associative scan. The scan is over chunks (L / chunk_size steps),
so activation memory stays O(B * Q * H * P) per step regardless of L, which
is what makes train_4k on 340B-class meshes and long_500k decode tractable.

Shapes (per mixer):
  u        (B, L, d_model)
  in_proj  -> z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)
  x viewed as (B, L, H, P);   B, C as (B, L, G, N);   H = G * heads_per_group
  state    (B, H, P, N)

The recurrence per head:  S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T,
y_t = C_t . S_t + D x_t, gated by silu(z) and RMS-normed before out_proj.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers.dense import dense_apply, dense_init
from repro.models.layers.norms import rms_norm, rms_norm_init


def ssd_dims(d_model: int, cfg: SSMConfig) -> dict:
    d_inner = cfg.expand * d_model
    nheads = cfg.num_heads or d_inner // cfg.head_dim
    head_dim = d_inner // nheads
    conv_ch = d_inner + 2 * cfg.ngroups * cfg.state_dim
    proj_out = 2 * d_inner + 2 * cfg.ngroups * cfg.state_dim + nheads
    return dict(d_inner=d_inner, nheads=nheads, head_dim=head_dim,
                conv_ch=conv_ch, proj_out=proj_out)


def ssd_init(key, d_model: int, cfg: SSMConfig, *, lora_ranks: dict,
             dtype=jnp.float32) -> dict:
    dims = ssd_dims(d_model, cfg)
    ks = jax.random.split(key, 5)
    params = {
        "in_proj": dense_init(ks[0], d_model, dims["proj_out"], dtype=dtype,
                              lora_rank=lora_ranks.get("ssm_in_proj", 0)),
        "out_proj": dense_init(ks[1], dims["d_inner"], d_model, dtype=dtype,
                               lora_rank=lora_ranks.get("ssm_out_proj", 0)),
        # depthwise causal conv over [x, B, C] channels
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_dim, dims["conv_ch"]))
                   * (1.0 / cfg.conv_dim) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((dims["conv_ch"],), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, dims["nheads"])).astype(jnp.float32),
        "D": jnp.ones((dims["nheads"],), dtype=jnp.float32),
        "dt_bias": jnp.zeros((dims["nheads"],), dtype=jnp.float32),
        "norm": rms_norm_init(dims["d_inner"], dtype=dtype),
    }
    return params


def _split_proj(proj: jnp.ndarray, d_model: int, cfg: SSMConfig):
    dims = ssd_dims(d_model, cfg)
    d_in, gn, h = dims["d_inner"], cfg.ngroups * cfg.state_dim, dims["nheads"]
    z, x, b, c, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, b, c, dt, dims


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                 init_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d. xbc (B, L, C); w (K, C).

    Returns (out (B, L, C), final_state (B, K-1, C)).
    """
    k = w.shape[0]
    b_, l, c = xbc.shape
    if init_state is None:
        init_state = jnp.zeros((b_, k - 1, c), xbc.dtype)
    padded = jnp.concatenate([init_state, xbc], axis=1)        # (B, L+K-1, C)
    out = jnp.zeros((b_, l, c), jnp.float32)
    for i in range(k):  # K is tiny (4): unrolled taps
        out = out + padded[:, i:i + l].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    final = padded[:, l:]  # last K-1 inputs
    return jax.nn.silu(out).astype(xbc.dtype), final


def _expand_groups(t: jnp.ndarray, nheads: int) -> jnp.ndarray:
    """(..., G, N) -> (..., H, N) broadcasting each group over its heads."""
    g = t.shape[-2]
    reps = nheads // g
    return jnp.repeat(t, reps, axis=-2)


def ssd_scan_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                     b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                     chunk: int,
                     init_state: Optional[jnp.ndarray] = None,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (pure jnp; the Pallas kernel mirrors this math).

    x (B, L, H, P); dt (B, L, H) post-softplus; a_log (H,);
    b, c (B, L, G, N). Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    B_, L, H, P = x.shape
    G, N = b.shape[-2:]
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))                     # (H,) < 0

    xf = x.astype(jnp.float32).reshape(B_, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, chunk, H)
    bf = b.astype(jnp.float32).reshape(B_, nc, chunk, G, N)
    cf = c.astype(jnp.float32).reshape(B_, nc, chunk, G, N)
    bh = _expand_groups(bf, H)                                  # (B,nc,Q,H,N)
    ch = _expand_groups(cf, H)

    a_inc = dtf * A                                             # (B,nc,Q,H) <=0
    cum = jnp.cumsum(a_inc, axis=2)                             # inclusive
    dtx = xf * dtf[..., None]                                   # dt folded in

    if init_state is None:
        init_state = jnp.zeros((B_, H, P, N), jnp.float32)

    def chunk_body(state, inp):
        xq, dtxq, bq, cq, cumq = inp
        # intra-chunk: Lmat_ij = exp(cum_i - cum_j) for i >= j.
        # Mask BEFORE exp: masked entries have diff > 0 (often huge), and
        # where(causal, exp(diff), 0) still produces inf in the forward
        # whose VJP multiplies 0 * inf = NaN -- the classic where-NaN trap
        # (this killed every SSM training step until caught by the smoke
        # tests' loss-decrease assertion).
        diff = cumq[:, :, None, :] - cumq[:, None, :, :]        # (B,Q,Q,H)
        idx = jnp.arange(cumq.shape[1])
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        lmat = jnp.exp(jnp.where(causal, diff, -1e30))          # (B,Q,Q,H)
        cb = jnp.einsum("bihn,bjhn->bijh", cq, bq)              # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", cb * lmat, dtxq)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(cumq)                                # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhpn,bqh->bqhp", cq, state, decay_in)
        # state update
        decay_out = jnp.exp(cumq[:, -1:, :] - cumq)             # (B,Q,H)
        new_contrib = jnp.einsum("bqhn,bqhp,bqh->bhpn", bq, dtxq, decay_out)
        chunk_decay = jnp.exp(cumq[:, -1, :])                   # (B,H)
        state = state * chunk_decay[..., None, None] + new_contrib
        return state, y_intra + y_inter

    # scan over chunks: inputs shaped (nc, B, Q, ...)
    inputs = (xf.transpose(1, 0, 2, 3, 4), dtx.transpose(1, 0, 2, 3, 4),
              bh.transpose(1, 0, 2, 3, 4), ch.transpose(1, 0, 2, 3, 4),
              cum.transpose(1, 0, 2, 3))
    final_state, ys = jax.lax.scan(chunk_body, init_state, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, L, H, P)
    y = y + xf.reshape(B_, L, H, P) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, d_skip: jnp.ndarray,
                    state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. x (B, H, P); dt (B, H); b, c (B, G, N);
    state (B, H, P, N). Returns (y (B, H, P), new_state)."""
    H = x.shape[1]
    A = -jnp.exp(a_log.astype(jnp.float32))
    bh = _expand_groups(b.astype(jnp.float32), H)               # (B,H,N)
    ch = _expand_groups(c.astype(jnp.float32), H)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)                                    # (B,H)
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bhn,bhp,bh->bhpn", bh, xf, dtf))
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_state)
    y = y + xf * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), new_state


def ssd_mixer_apply(params: dict, u: jnp.ndarray, d_model: int,
                    cfg: SSMConfig, *, lora_rank: int = -1,
                    lora_scale: float = 1.0,
                    conv_state: Optional[jnp.ndarray] = None,
                    ssm_state: Optional[jnp.ndarray] = None,
                    use_kernel: bool = False):
    """Full SSD mixer over a sequence. u (B, L, d_model).

    Returns (y (B, L, d_model), (conv_state, ssm_state)).
    """
    lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
    proj = dense_apply(params["in_proj"], u, **lk)
    z, x, b, c, dt, dims = _split_proj(proj, d_model, cfg)
    H, P = dims["nheads"], dims["head_dim"]
    xbc = jnp.concatenate([x, b, c], axis=-1)
    xbc, conv_final = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   conv_state)
    x, b, c = jnp.split(xbc, [dims["d_inner"],
                              dims["d_inner"] + cfg.ngroups * cfg.state_dim],
                        axis=-1)
    B_, L = u.shape[0], u.shape[1]
    x = x.reshape(B_, L, H, P)
    b = b.reshape(B_, L, cfg.ngroups, cfg.state_dim)
    c = c.reshape(B_, L, cfg.ngroups, cfg.state_dim)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        y, ssm_final = kernel_ops.ssd_scan(
            x, dt_act, params["A_log"], b, c, params["D"], cfg.chunk_size,
            init_state=ssm_state)
    else:
        y, ssm_final = ssd_scan_chunked(
            x, dt_act, params["A_log"], b, c, params["D"], cfg.chunk_size,
            init_state=ssm_state)
    y = y.reshape(B_, L, dims["d_inner"])
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    out = dense_apply(params["out_proj"], y, **lk)
    return out, (conv_final, ssm_final)


def ssd_mixer_decode(params: dict, u: jnp.ndarray, d_model: int,
                     cfg: SSMConfig, conv_state: jnp.ndarray,
                     ssm_state: jnp.ndarray, *, lora_rank: int = -1,
                     lora_scale: float = 1.0):
    """One-token decode. u (B, 1, d_model); conv_state (B, K-1, conv_ch);
    ssm_state (B, H, P, N)."""
    lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
    proj = dense_apply(params["in_proj"], u, **lk)
    z, x, b, c, dt, dims = _split_proj(proj, d_model, cfg)
    H, P = dims["nheads"], dims["head_dim"]
    xbc = jnp.concatenate([x, b, c], axis=-1)                   # (B,1,C)
    # conv over [state, new]: window = last K inputs
    w, bias = params["conv_w"], params["conv_b"]
    window = jnp.concatenate([conv_state, xbc], axis=1)         # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32)) + bias.astype(jnp.float32)
    xbc_out = jax.nn.silu(conv_out).astype(u.dtype)             # (B,C)
    new_conv_state = window[:, 1:]
    x1, b1, c1 = jnp.split(
        xbc_out, [dims["d_inner"], dims["d_inner"] + cfg.ngroups * cfg.state_dim],
        axis=-1)
    B_ = u.shape[0]
    x1 = x1.reshape(B_, H, P)
    b1 = b1.reshape(B_, cfg.ngroups, cfg.state_dim)
    c1 = c1.reshape(B_, cfg.ngroups, cfg.state_dim)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    y, new_ssm = ssd_decode_step(x1, dt1, params["A_log"], b1, c1,
                                 params["D"], ssm_state)
    y = y.reshape(B_, 1, dims["d_inner"])
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    out = dense_apply(params["out_proj"], y, **lk)
    return out, (new_conv_state, new_ssm)
