"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191) splits the head-dim frequency
bands into three sections (temporal, height, width) and indexes each section
with its own position id. For pure-text tokens all three ids coincide, which
makes M-RoPE degenerate to standard RoPE -- a property we test.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,) in f32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Apply rotation given broadcastable cos/sin of shape (..., head_dim//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Standard RoPE.

    x: (B, L, H, D); positions: (B, L) int32. Rotation in f32, cast back.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, L, D/2)
    cos = jnp.cos(angles)[:, :, None, :]               # (B, L, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """M-RoPE. positions: (3, B, L) for (temporal, h, w) ids.

    ``sections`` gives the number of frequency pairs assigned to each of the
    three position streams; sum(sections) must equal head_dim // 2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # section id per frequency index: 0,0,..,1,1,..,2,2
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=d // 2)    # (D/2,)
    # pick position stream per frequency: (B, L, D/2)
    pos_blc = positions.transpose(1, 2, 0).astype(jnp.float32)  # (B, L, 3)
    idx = jnp.broadcast_to(sec_id, pos_blc.shape[:2] + (d // 2,))
    pos = jnp.take_along_axis(pos_blc, idx, axis=-1)            # (B, L, D/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
