"""Unified model assembly for all assigned architectures.

One code path builds dense / moe / ssm / hybrid / vlm / audio models from a
``ModelConfig``: per-layer parameters are stacked on a leading axis and the
layer stack runs under ``jax.lax.scan`` (with configurable remat policy), so
96-layer 340B-class graphs compile with bounded HLO size.

Entry points
  Model.init(key)                     -> params pytree (LoRA factors inline)
  Model.train_loss(params, batch)     -> (loss, metrics)
  Model.prefill(params, batch)        -> (logits, cache)
  Model.decode_step(params, batch, cache) -> (logits, cache)
  Model.init_cache(batch, max_len)    -> zeroed cache pytree
  Model.param_shapes() / cache_shapes -> ShapeDtypeStructs (no allocation)

LoRA: adapters sized r_max live inline in the params ( ``lora_a``/``lora_b``
leaves); a client of rank r_k runs with ``lora_rank=r_k`` which statically
truncates the factors (Algorithm 1 line 4 of the paper).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_BIDIR, ATTN_SLIDING, LoRAConfig,
                                ModelConfig)
from repro.models.layers.attention import blockwise_attention, decode_attention
from repro.models.layers.dense import dense_apply, dense_init, lora_init
from repro.models.layers.mla import mla_attention, mla_decode, mla_init
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.moe import moe_apply, moe_init
from repro.models.layers.norms import rms_norm, rms_norm_init
from repro.models.layers.rope import apply_mrope, apply_rope
from repro.models.layers.ssd import (ssd_dims, ssd_init, ssd_mixer_apply,
                                     ssd_mixer_decode)

Params = Dict[str, Any]


def _lora_ranks_for(cfg: ModelConfig, lora: Optional[LoRAConfig]) -> dict:
    if lora is None:
        return {}
    return {t: lora.r_max for t in cfg.lora_targets}


# ---------------------------------------------------------------------------
# Per-layer blocks
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig, lora: Optional[LoRAConfig] = None,
                 *, dtype=jnp.float32, remat: bool = True,
                 use_kernels: bool = False,
                 block_q: int = 512, block_kv: int = 1024,
                 moe_impl: str = "tp", mesh=None, batch_axes=("data",),
                 residual_sharding=None, logits_sharding=None,
                 attn_q_sharding=None, moe_capacity_factor: float = 0.0,
                 attn_repeat_kv: bool = False, bf16_scores: bool = False):
        self.cfg = cfg
        self.lora = lora
        self.dtype = dtype
        self.remat = remat
        self.use_kernels = use_kernels
        self.block_q = block_q
        self.block_kv = block_kv
        # distribution hooks (launch/dryrun wires these; None on CPU)
        self.moe_impl = moe_impl          # "tp" (GSPMD) | "ep" (shard_map)
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.residual_sharding = residual_sharding  # NamedSharding | None
        self.logits_sharding = logits_sharding      # NamedSharding | None
        # Megatron-style: shard q heads over "model" so scores never psum
        self.attn_q_sharding = attn_q_sharding      # NamedSharding | None
        # >0: capacity-grouped EP dispatch (§Perf iteration A)
        self.moe_capacity_factor = moe_capacity_factor
        # repeat KV heads to full MHA so the head axis shards cleanly when
        # num_heads doesn't tile the model axis (§Perf: kills score psums)
        self.attn_repeat_kv = attn_repeat_kv
        self.bf16_scores = bf16_scores
        self.lora_ranks = _lora_ranks_for(cfg, lora)
        # layer grouping for scan: llama4 interleaves dense/moe with period 2
        moe = cfg.moe
        self.group_size = moe.moe_layer_period if (moe and moe.moe_layer_period > 1) else 1
        assert cfg.num_layers % self.group_size == 0
        self.num_groups = cfg.num_layers // self.group_size

    # -- init ---------------------------------------------------------------

    def _layer_init(self, key, layer_idx: int) -> Params:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 8)
        p: Params = {"norm1": rms_norm_init(cfg.d_model, dtype=dt)}
        lr = self.lora_ranks
        if cfg.kind == "ssm":
            p["ssm"] = ssd_init(ks[0], cfg.d_model, cfg.ssm, lora_ranks=lr,
                                dtype=dt)
            return p  # mamba2 block: norm + mixer + residual only
        # attention mixer
        if cfg.mla is not None:
            p["attn"] = mla_init(ks[0], cfg.d_model, cfg.num_heads, cfg.mla,
                                 lora_ranks=lr, dtype=dt)
        else:
            hd = cfg.resolved_head_dim
            q_out = cfg.num_heads * hd
            kv_out = cfg.num_kv_heads * hd
            p["attn"] = {
                "q": dense_init(ks[0], cfg.d_model, q_out, bias=cfg.qkv_bias,
                                dtype=dt, lora_rank=lr.get("q_proj", 0)),
                "k": dense_init(ks[1], cfg.d_model, kv_out, bias=cfg.qkv_bias,
                                dtype=dt, lora_rank=lr.get("k_proj", 0)),
                "v": dense_init(ks[2], cfg.d_model, kv_out, bias=cfg.qkv_bias,
                                dtype=dt, lora_rank=lr.get("v_proj", 0)),
                "o": dense_init(ks[3], q_out, cfg.d_model, dtype=dt,
                                lora_rank=lr.get("o_proj", 0)),
            }
        if cfg.kind == "hybrid":
            p["ssm"] = ssd_init(ks[4], cfg.d_model, cfg.ssm, lora_ranks=lr,
                                dtype=dt)
        # FFN
        p["norm2"] = rms_norm_init(cfg.d_model, dtype=dt)
        if cfg.moe is not None and cfg.moe.is_moe_layer(layer_idx):
            p["moe"] = moe_init(ks[5], cfg.d_model, cfg.moe, cfg.activation,
                                lora_ranks=lr, dtype=dt)
        else:
            d_ff = cfg.d_ff
            if cfg.moe is not None:  # llama4 dense layers: 2x expert width
                d_ff = cfg.moe.expert_d_ff * 2
            p["mlp"] = mlp_init(ks[5], cfg.d_model, d_ff, cfg.activation,
                                lora_ranks=lr, dtype=dt)
        return p

    def _group_init(self, key, group_idx: int) -> Params:
        if self.group_size == 1:
            return self._layer_init(key, group_idx)
        ks = jax.random.split(key, self.group_size)
        return {f"sub{i}": self._layer_init(ks[i], group_idx * self.group_size + i)
                for i in range(self.group_size)}

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        params: Params = {
            "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(dt),
            "final_norm": rms_norm_init(cfg.d_model, dtype=dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                           dtype=dt)
        gks = jax.random.split(k_layers, self.num_groups)
        groups = [self._group_init(gks[i], i) for i in range(self.num_groups)]
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
        if self.lora is not None and self.lora.variant != "lora":
            params = self._apply_peft_variant(params)
        if cfg.frontend.kind != "none":
            # projector stub: precomputed embeddings enter at embed_dim ->
            # identity-shaped projector kept trainable-frozen
            params["frontend_proj"] = dense_init(
                jax.random.fold_in(key, 11), cfg.frontend.embed_dim,
                cfg.d_model, dtype=dt)
        return params

    def _apply_peft_variant(self, params: Params) -> Params:
        """Table 5 variants: DoRA adds trainable magnitudes next to every
        adapter; QLoRA fake-quantizes the frozen base of adapted layers."""
        from repro.models.layers.dense import (dora_magnitude_init,
                                               quantize_dequantize)
        variant = self.lora.variant
        bits = self.lora.quant_bits

        def walk(node):
            if not isinstance(node, dict):
                return node
            out = {k: walk(v) for k, v in node.items()}
            if "w" in out and "lora_a" in out:
                if variant == "dora":
                    out["lora_m"] = dora_magnitude_init(out["w"])
                elif variant == "qlora":
                    out["w"] = quantize_dequantize(out["w"], bits)
            return out

        return walk(params)

    def param_shapes(self) -> Params:
        """ShapeDtypeStructs for the full config -- no allocation."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- forward pieces -------------------------------------------------------

    def _apply_rope(self, t: jnp.ndarray, positions) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.rope_type == "none":
            return t
        if cfg.rope_type == "mrope":
            return apply_mrope(t, positions, cfg.rope_theta, cfg.mrope_sections)
        return apply_rope(t, positions, cfg.rope_theta)

    def _attn_seq(self, p: Params, x: jnp.ndarray, positions, *,
                  lora_rank: int, lora_scale: float, is_global,
                  q_offset: int = 0):
        """Full-sequence attention; returns (out, (k, v)) for cache fill."""
        cfg = self.cfg
        b, l = x.shape[:2]
        hd = cfg.resolved_head_dim
        lk = dict(lora_rank=lora_rank, lora_scale=lora_scale,
                  use_kernel=self.use_kernels)
        q_flat = dense_apply(p["q"], x, **lk)
        if self.attn_q_sharding is not None and not self.attn_repeat_kv:
            # constrain the FLAT (B, L, H*hd) projection: always evenly
            # divisible; GSPMD maps it onto (heads, hd) subgroups itself
            q_flat = jax.lax.with_sharding_constraint(q_flat,
                                                      self.attn_q_sharding)
        q = q_flat.reshape(b, l, cfg.num_heads, hd)
        k = dense_apply(p["k"], x, **lk).reshape(b, l, cfg.num_kv_heads, hd)
        v = dense_apply(p["v"], x, **lk).reshape(b, l, cfg.num_kv_heads, hd)
        q = self._apply_rope(q, positions)
        k = self._apply_rope(k, positions)
        if self.attn_repeat_kv and cfg.num_kv_heads < cfg.num_heads:
            reps = cfg.num_heads // cfg.num_kv_heads
            k = jnp.repeat(k, reps, axis=2)
            v = jnp.repeat(v, reps, axis=2)
        if self.attn_repeat_kv and self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            head_sh = NamedSharding(self.mesh, PartitionSpec(
                self.batch_axes, None, "model", None))
            q = jax.lax.with_sharding_constraint(q, head_sh)
            k = jax.lax.with_sharding_constraint(k, head_sh)
            v = jax.lax.with_sharding_constraint(v, head_sh)
        causal = cfg.attn_type != ATTN_BIDIR
        window = 0
        if cfg.attn_type == ATTN_SLIDING and cfg.sliding_window:
            # global layers (is_global) disable the window via a huge value
            window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.sliding_window))
        out = blockwise_attention(
            q, k, v, causal=causal, sliding_window=window, q_offset=q_offset,
            block_q=self.block_q, block_kv=self.block_kv,
            softcap=cfg.logit_softcap, bf16_scores=self.bf16_scores)
        out = out.reshape(b, l, cfg.num_heads * hd)
        return dense_apply(p["o"], out, **lk), (k, v)

    def _attn_decode(self, p: Params, x: jnp.ndarray, cache_l, cache_len,
                     positions, *, lora_rank: int, lora_scale: float,
                     is_global):
        cfg = self.cfg
        b = x.shape[0]
        hd = cfg.resolved_head_dim
        lk = dict(lora_rank=lora_rank, lora_scale=lora_scale,
                  use_kernel=self.use_kernels)
        q = dense_apply(p["q"], x, **lk).reshape(b, 1, cfg.num_heads, hd)
        k = dense_apply(p["k"], x, **lk).reshape(b, 1, cfg.num_kv_heads, hd)
        v = dense_apply(p["v"], x, **lk).reshape(b, 1, cfg.num_kv_heads, hd)
        q = self._apply_rope(q, positions)
        k = self._apply_rope(k, positions)
        s_cache = cache_l["k"].shape[1]
        write_idx = cache_len % s_cache          # ring buffer when S < max_len
        if jnp.ndim(cache_len) == 0:
            k_cache = jax.lax.dynamic_update_slice(
                cache_l["k"], k.astype(cache_l["k"].dtype),
                (0, write_idx, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache_l["v"], v.astype(cache_l["v"].dtype),
                (0, write_idx, 0, 0))
        else:
            # per-slot cache lengths (continuous-batching serving): each
            # batch row writes its own ring position
            rows = jnp.arange(b)
            k_cache = cache_l["k"].at[rows, write_idx].set(
                k[:, 0].astype(cache_l["k"].dtype))
            v_cache = cache_l["v"].at[rows, write_idx].set(
                v[:, 0].astype(cache_l["v"].dtype))
        window = None
        if (cfg.attn_type == ATTN_SLIDING and cfg.sliding_window
                and s_cache > cfg.sliding_window):
            # full-size cache: apply the window by masking
            window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.sliding_window))
        eff_len = jnp.minimum(cache_len, s_cache - 1)
        out = self._masked_decode_attn(q, k_cache, v_cache, eff_len, window)
        out = out.reshape(b, 1, cfg.num_heads * hd)
        return dense_apply(p["o"], out, **lk), {"k": k_cache, "v": v_cache}

    def _masked_decode_attn(self, q, k_cache, v_cache, cache_len, window):
        s = k_cache.shape[1]
        total = cache_len + 1
        if window is None:
            return decode_attention(q, k_cache, v_cache, total,
                                    softcap=self.cfg.logit_softcap)
        # sliding window: valid positions in (total - window, total)
        pos = jnp.arange(s)
        lo = total - window
        # emulate via cache_len mask + explicit lower bound: push invalid
        # keys out by masking scores through a large-negative v trick is
        # fragile; instead reuse decode_attention's upper mask and add the
        # lower mask by zeroing keys' contribution via a second mask pass.
        out = _decode_attention_windowed(q, k_cache, v_cache, total, lo,
                                         softcap=self.cfg.logit_softcap)
        return out

    def _mrope_decode_positions(self, cache_len, b):
        # decode: all three mrope streams advance with the token index
        pos = jnp.full((b,), cache_len, jnp.int32)
        if self.cfg.rope_type == "mrope":
            return jnp.broadcast_to(pos, (3, b))[:, :, None] * jnp.ones(
                (3, b, 1), jnp.int32)
        return pos[:, None]

    # -- block application ----------------------------------------------------

    def _block_seq(self, p: Params, x, positions, aux, *, layer_idx,
                   lora_rank, lora_scale, mode):
        """One layer, full sequence. Returns (x, aux, cache_entry)."""
        cfg = self.cfg
        lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
        is_global = self._is_global(layer_idx)
        cache_entry = {}
        h = rms_norm(p["norm1"], x, eps=cfg.rms_norm_eps)
        if cfg.kind == "ssm":
            mixed, (conv_s, ssm_s) = ssd_mixer_apply(
                p["ssm"], h, cfg.d_model, cfg.ssm, use_kernel=self.use_kernels,
                **lk)
            if mode == "prefill":
                cache_entry = {"conv": conv_s, "ssm": ssm_s}
            return x + mixed, aux, cache_entry
        if cfg.mla is not None:
            attn_out, (ckv, krope) = mla_attention(
                p["attn"], h, positions, cfg.num_heads, cfg.mla,
                rope_theta=cfg.rope_theta,
                causal=cfg.attn_type != ATTN_BIDIR,
                sliding_window=cfg.sliding_window if cfg.attn_type == ATTN_SLIDING else 0,
                **lk)
            if mode == "prefill":
                cache_entry["ckv"] = ckv
                cache_entry["krope"] = krope
        else:
            attn_out, (k, v) = self._attn_seq(
                p["attn"], h, positions, is_global=is_global, **lk)
            if mode == "prefill":
                cache_entry["k"] = k
                cache_entry["v"] = v
        if cfg.kind == "hybrid":
            ssm_out, (conv_s, ssm_s) = ssd_mixer_apply(
                p["ssm"], h, cfg.d_model, cfg.ssm, use_kernel=self.use_kernels,
                **lk)
            r = cfg.hybrid_attn_ratio
            mixed = r * attn_out + (1.0 - r) * ssm_out
            if mode == "prefill":
                cache_entry["conv"] = conv_s
                cache_entry["ssm"] = ssm_s
        else:
            mixed = attn_out
        x = x + mixed
        h2 = rms_norm(p["norm2"], x, eps=cfg.rms_norm_eps)
        if "moe" in p:
            ffn_out, moe_aux = self._moe(p["moe"], h2, **lk)
            aux = aux + moe_aux * cfg.moe.router_aux_loss_coef
        else:
            ffn_out = mlp_apply(p["mlp"], h2, cfg.activation, **lk)
        x = x + ffn_out
        if self.residual_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, self.residual_sharding)
        return x, aux, cache_entry

    def _moe(self, p_moe, h2, **lk):
        if self.moe_impl == "ep":
            from repro.models.layers.moe import moe_apply_ep
            return moe_apply_ep(p_moe, h2, self.cfg.moe, self.cfg.activation,
                                self.mesh, batch_axes=self.batch_axes,
                                capacity_factor=self.moe_capacity_factor,
                                **lk)
        return moe_apply(p_moe, h2, self.cfg.moe, self.cfg.activation, **lk)

    def _block_decode(self, p: Params, x, cache_l, cache_len, positions, *,
                      layer_idx, lora_rank, lora_scale):
        cfg = self.cfg
        lk = dict(lora_rank=lora_rank, lora_scale=lora_scale)
        is_global = self._is_global(layer_idx)
        new_cache = dict(cache_l)
        h = rms_norm(p["norm1"], x, eps=cfg.rms_norm_eps)
        if cfg.kind == "ssm":
            mixed, (conv_s, ssm_s) = ssd_mixer_decode(
                p["ssm"], h, cfg.d_model, cfg.ssm, cache_l["conv"],
                cache_l["ssm"], **lk)
            new_cache.update(conv=conv_s, ssm=ssm_s)
            return x + mixed, new_cache
        if cfg.mla is not None:
            # cache_len may be scalar (single-sequence decode) or (B,)
            # per-slot lengths (continuous-batching serving): mla_decode
            # vectorizes the cache write, decode_attention the mask
            s_cache = cache_l["ckv"].shape[1]
            attn_out, (ckv, krope) = mla_decode(
                p["attn"], h, positions[:, 0] if positions.ndim > 1 else positions,
                cache_l["ckv"], cache_l["krope"],
                jnp.minimum(cache_len, s_cache - 1), cfg.num_heads,
                cfg.mla, rope_theta=cfg.rope_theta,
                write_idx=cache_len % s_cache, **lk)
            new_cache.update(ckv=ckv, krope=krope)
        else:
            attn_out, kv = self._attn_decode(
                p["attn"], h, cache_l, cache_len, positions,
                is_global=is_global, **lk)
            new_cache.update(kv)
        if cfg.kind == "hybrid":
            ssm_out, (conv_s, ssm_s) = ssd_mixer_decode(
                p["ssm"], h, cfg.d_model, cfg.ssm, cache_l["conv"],
                cache_l["ssm"], **lk)
            r = cfg.hybrid_attn_ratio
            mixed = r * attn_out + (1.0 - r) * ssm_out
            new_cache.update(conv=conv_s, ssm=ssm_s)
        else:
            mixed = attn_out
        x = x + mixed
        h2 = rms_norm(p["norm2"], x, eps=cfg.rms_norm_eps)
        if "moe" in p:
            ffn_out, _ = self._moe(p["moe"], h2, **lk)
        else:
            ffn_out = mlp_apply(p["mlp"], h2, cfg.activation, **lk)
        return x + ffn_out, new_cache

    def _is_global(self, layer_idx) -> jnp.ndarray:
        if self.cfg.global_attn_every:
            return (layer_idx % self.cfg.global_attn_every) == 0
        return jnp.asarray(False)

    # -- embeddings / head ----------------------------------------------------

    def _embed_inputs(self, params: Params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        parts = []
        if cfg.frontend.kind != "none" and "embeds" in batch:
            fe = dense_apply(params["frontend_proj"],
                             batch["embeds"].astype(self.dtype))
            parts.append(fe)
        if "tokens" in batch:
            tok = jnp.take(params["embed"], batch["tokens"], axis=0)
            if self.mesh is not None and self.residual_sharding is not None:
                # pin the gather output to batch-only sharding: GSPMD must
                # not back-propagate feature sharding into the lookup table
                # (XLA mis-partitions jvp-of-gather on feature-sharded
                # tables -- see DESIGN.md §5)
                from jax.sharding import NamedSharding, PartitionSpec
                tok = jax.lax.with_sharding_constraint(
                    tok, NamedSharding(self.mesh, PartitionSpec(
                        self.batch_axes, None, None)))
            if cfg.kind == "dense" and cfg.name.startswith("gemma"):
                tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)
            parts.append(tok.astype(self.dtype))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    def _logits(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        x = rms_norm(params["final_norm"], x, eps=self.cfg.rms_norm_eps)
        if self.cfg.tie_embeddings:
            if (self.mesh is not None and self.residual_sharding is not None
                    and self.logits_sharding is None):
                # odd-vocab tied head: keep x feature-replicated so GSPMD
                # never feature-shards the (gathered) embedding table
                from jax.sharding import NamedSharding, PartitionSpec
                x = jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh, PartitionSpec(
                        self.batch_axes, None, None)))
            logits = x @ params["embed"].astype(x.dtype).T
        else:
            logits = dense_apply(params["lm_head"], x)
        if self.logits_sharding is not None:
            # keep logits vocab-sharded: a (B, L, 256k) f32 tensor must never
            # materialize unsharded (loss reductions psum over the shards)
            logits = jax.lax.with_sharding_constraint(logits,
                                                      self.logits_sharding)
        return logits

    def _default_positions(self, batch: dict, b: int, l: int):
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
        if self.cfg.rope_type == "mrope":
            return jnp.broadcast_to(pos, (3, b, l))
        return pos

    # -- public entry points ---------------------------------------------------

    def forward_seq(self, params: Params, batch: dict, *, mode: str = "train",
                    lora_rank: int = -1, lora_scale: float = 1.0):
        """Full-sequence forward. mode: "train" (no cache) | "prefill"."""
        x = self._embed_inputs(params, batch)
        b, l = x.shape[:2]
        positions = self._default_positions(batch, b, l)
        aux0 = jnp.zeros((), jnp.float32)

        def group_body(carry, inp):
            x, aux = carry
            p_group, group_idx = inp
            caches = {}
            for i in range(self.group_size):
                p_l = p_group[f"sub{i}"] if self.group_size > 1 else p_group
                layer_idx = group_idx * self.group_size + i
                x, aux, cache_entry = self._block_seq(
                    p_l, x, positions, aux, layer_idx=layer_idx,
                    lora_rank=lora_rank, lora_scale=lora_scale, mode=mode)
                if self.group_size > 1:
                    caches[f"sub{i}"] = cache_entry
                else:
                    caches = cache_entry
            return (x, aux), caches

        body = group_body
        if self.remat:
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), caches = jax.lax.scan(
            body, (x, aux0),
            (params["layers"], jnp.arange(self.num_groups)))
        logits = self._logits(params, x)
        return logits, aux, caches

    def train_loss(self, params: Params, batch: dict, *, lora_rank: int = -1,
                   lora_scale: float = 1.0):
        logits, aux, _ = self.forward_seq(
            params, batch, mode="train", lora_rank=lora_rank,
            lora_scale=lora_scale)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(targets.shape, jnp.float32)
        logits_f = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits_f, axis=-1)
        # gold logit via one-hot contraction: reduction over the (possibly
        # model-sharded) vocab dim lowers to a psum instead of a cross-shard
        # gather (take_along_axis would all-gather the logits)
        vocab = logits_f.shape[-1]
        onehot = jax.nn.one_hot(targets, vocab, dtype=logits_f.dtype)
        gold = jnp.sum(logits_f * onehot, axis=-1)
        nll = (logz - gold) * mask
        loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
        metrics = {"loss": loss, "aux_loss": aux,
                   "accuracy": (jnp.argmax(logits_f, -1) == targets)
                   .astype(jnp.float32).__mul__(mask).sum()
                   / jnp.maximum(mask.sum(), 1.0)}
        return loss + aux, metrics

    def prefill(self, params: Params, batch: dict, *, lora_rank: int = -1,
                lora_scale: float = 1.0):
        logits, _, caches = self.forward_seq(
            params, batch, mode="prefill", lora_rank=lora_rank,
            lora_scale=lora_scale)
        return logits, caches

    def decode_step(self, params: Params, batch: dict, cache: dict, *,
                    lora_rank: int = -1, lora_scale: float = 1.0):
        """One decode step. batch: {"token": (B, 1)} [+ modality stubs].

        cache: {"layers": stacked per-layer cache, "len": scalar int32}.
        Returns (logits (B, 1, V), new cache).
        """
        assert self.cfg.supports_decode, f"{self.cfg.name} is encoder-only"
        cache_len = cache["len"]
        tok = batch["token"]
        x = jnp.take(params["embed"], tok, axis=0).astype(self.dtype)
        if self.cfg.kind == "dense" and self.cfg.name.startswith("gemma"):
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)
        b = x.shape[0]
        # cache["len"] is a scalar (lock-step decode) or (B,) vector
        # (per-slot lengths under continuous batching) -- both reshape to
        # one position column
        pos_col = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(cache_len, jnp.int32), (-1,)), (b,))[:, None]
        if self.cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(pos_col, (3, b, 1))
        else:
            positions = pos_col

        def group_body(x, inp):
            p_group, cache_group, group_idx = inp
            new_group = {}
            for i in range(self.group_size):
                p_l = p_group[f"sub{i}"] if self.group_size > 1 else p_group
                c_l = cache_group[f"sub{i}"] if self.group_size > 1 else cache_group
                layer_idx = group_idx * self.group_size + i
                x, c_new = self._block_decode(
                    p_l, x, c_l, cache_len, positions, layer_idx=layer_idx,
                    lora_rank=lora_rank, lora_scale=lora_scale)
                if self.group_size > 1:
                    new_group[f"sub{i}"] = c_new
                else:
                    new_group = c_new
            return x, new_group

        x, new_layer_caches = jax.lax.scan(
            group_body, x,
            (params["layers"], cache["layers"], jnp.arange(self.num_groups)))
        logits = self._logits(params, x)
        return logits, {"layers": new_layer_caches, "len": cache_len + 1}

    # -- cache construction ----------------------------------------------------

    def _layer_cache_shape(self, batch_size: int, max_len: int) -> dict:
        cfg, dt = self.cfg, self.dtype
        entry: dict = {}
        if cfg.kind == "ssm" or cfg.kind == "hybrid":
            dims = ssd_dims(cfg.d_model, cfg.ssm)
            entry["conv"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.ssm.conv_dim - 1, dims["conv_ch"]), dt)
            entry["ssm"] = jax.ShapeDtypeStruct(
                (batch_size, dims["nheads"], dims["head_dim"],
                 cfg.ssm.state_dim), jnp.float32)
        if cfg.kind == "ssm":
            return entry
        s = self.cache_seq_len(max_len)
        if cfg.mla is not None:
            entry["ckv"] = jax.ShapeDtypeStruct(
                (batch_size, s, cfg.mla.kv_lora_rank), dt)
            entry["krope"] = jax.ShapeDtypeStruct(
                (batch_size, s, cfg.mla.qk_rope_head_dim), dt)
        else:
            hd = cfg.resolved_head_dim
            entry["k"] = jax.ShapeDtypeStruct(
                (batch_size, s, cfg.num_kv_heads, hd), dt)
            entry["v"] = jax.ShapeDtypeStruct(
                (batch_size, s, cfg.num_kv_heads, hd), dt)
        return entry

    def cache_seq_len(self, max_len: int) -> int:
        """Ring-buffer length: pure sliding-window archs only ever need the
        last ``window`` positions (what makes long_500k decode O(window))."""
        cfg = self.cfg
        if (cfg.attn_type == ATTN_SLIDING and cfg.sliding_window
                and not cfg.global_attn_every):
            return min(max_len, cfg.sliding_window)
        return max_len

    def cache_shapes(self, batch_size: int, max_len: int) -> dict:
        per_layer = self._layer_cache_shape(batch_size, max_len)
        if self.group_size > 1:
            per_layer = {f"sub{i}": per_layer for i in range(self.group_size)}
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.num_groups,) + s.shape,
                                           s.dtype), per_layer)
        return {"layers": stacked,
                "len": jax.ShapeDtypeStruct((), jnp.int32)}

    def init_cache(self, batch_size: int, max_len: int) -> dict:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch_size, max_len))


# ---------------------------------------------------------------------------
# windowed decode attention helper
# ---------------------------------------------------------------------------

def _decode_attention_windowed(q, k_cache, v_cache, total, lo, *,
                               softcap: float = 0.0):
    """decode attention with validity window [lo, total)."""
    b, _, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    scale = d ** -0.5
    qg = q.reshape(b, kvh, h // kvh, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(s)
    # total / lo are scalars or (B,) per-slot lengths; broadcast over rows
    total_b = jnp.reshape(jnp.asarray(total), (-1, 1))
    lo_b = jnp.reshape(jnp.asarray(lo), (-1, 1))
    valid = (pos[None, :] < total_b) & (pos[None, :] >= jnp.maximum(lo_b, 0))
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def build_model(cfg: ModelConfig, lora: Optional[LoRAConfig] = None,
                **kw) -> Model:
    return Model(cfg, lora, **kw)
