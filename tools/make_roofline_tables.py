"""Generate the §Dry-run / §Roofline markdown tables from the dry-run JSONs.

  PYTHONPATH=src python tools/make_roofline_tables.py > roofline_tables.md
"""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fmt_t(sec):
    if sec >= 1.0:
        return f"{sec:8.2f}s "
    return f"{sec*1e3:8.2f}ms"


def table(path, mesh_name):
    rows = json.load(open(path))
    out = []
    out.append(f"\n### Mesh {mesh_name}\n")
    out.append("| arch | shape | status | bottleneck | t_compute | t_memory "
               "| t_collective | MODEL_FLOPs | useful ratio | note |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "OK":
            note = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | "
                       f"— | — | — | — | — | {note} |")
            continue
        mf = r.get("model_flops", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | OK | **{r['bottleneck']}** | "
            f"{fmt_t(r['t_compute_s'])} | {fmt_t(r['t_memory_s'])} | "
            f"{fmt_t(r['t_collective_s'])} | {mf:.2e} | "
            f"{r.get('useful_ratio', 0):.3f} | "
            f"{r.get('step','')} mb={r.get('microbatches','-')} |")
    return "\n".join(out)


def main():
    for mesh_name, fname in (("16x16 (256 chips, single pod)",
                              "dryrun_single_pod.json"),
                             ("2x16x16 (512 chips, multi-pod)",
                              "dryrun_multi_pod.json")):
        path = os.path.join(ROOT, fname)
        if os.path.exists(path):
            print(table(path, mesh_name))
        else:
            print(f"\n### Mesh {mesh_name}\n\n(not yet generated)")


if __name__ == "__main__":
    main()
