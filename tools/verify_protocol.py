#!/usr/bin/env python
"""Protocol-verification sweep (DESIGN.md §10): exhaustively model-check
the event-driven round path over bounded interleavings, run the
RNG/determinism lint over round-path jaxprs and host sources, and write
the tracked ``AUDIT_protocol.json``.

    PYTHONPATH=src python tools/verify_protocol.py [--out PATH]
        [--fast] [--verbose]

Matrix:

  protocol   3 trigger families (count / timeout / staleness-bound)
             x lifecycles {none+symmetric, dropout->rejoin, mid-run join}
             at 3 clients x 2 plans over a 3-value latency grid, plus a
             3-plan ladder per trigger on a 2-value grid. Every unique
             arrival schedule (after partial-order reduction) drives a
             REAL EventScheduler through the server's consumption
             protocol; every reachable event boundary is checkpoint-cut
             and replayed (``--fast``: 2-value grids, no 3-plan ladder).
  rng-flow   key-provenance dataflow over round-path init jaxprs
             (dense/LoRA/MLP param init -- the functions that fan one
             seed out to per-layer streams).
  rng-host   host-determinism AST rules over every module active while
             the virtual clock runs (federation/, core aggregation,
             trace replay, checkpoint I/O, the verifier itself).

Positive controls (the sweep FAILS if any does not trip): an injected
double-fire (re-delivering consumed arrivals), ghost/absent weight leak,
cancelled-arrival delivery, a torn checkpoint snapshot, an understated
staleness bound, a jaxpr key reuse, a host-clock read, an unseeded
default_rng, a SeedSequence shape collision, and set-order iteration.

Exit status: 0 sweep green + all controls tripped, 1 otherwise, 2 on
usage errors. ``tools/ci.sh verify`` runs the full sweep (tier-1);
``verify-fast`` runs ``--fast`` to a temp path inside smoke.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis import rng_lint
from repro.analysis.protocol import (CancelledDeliveryScheduler,
                                     DoubleConsumeScheduler, Scenario,
                                     check_scenario)
from repro.analysis.report import AuditReport, ProgramAudit
from repro.federation.events import (ClientLifecycle, CountTrigger,
                                     LifecycleEvent, StalenessBoundTrigger,
                                     TimeoutTrigger)

# federation shape of every scenario: 3 clients, heterogeneous ranks and
# sample counts (weights must conserve under heterogeneity, not just
# uniformity); the symmetric variant equalizes n_k of clients {0, 2} so
# the symmetry reduction applies
NUM_CLIENTS = 3
RANKS = (8, 4, 8)
N_K_HET = (3, 1, 2)
N_K_SYM = (3, 1, 3)
GRID_FULL = (0.5, 1.5, 2.5)
GRID_FAST = (0.5, 1.5)
GRID_LADDER = (0.5, 2.5)

TRIGGERS = {
    "count": (lambda: CountTrigger(3), None),
    "timeout": (lambda: TimeoutTrigger(1.5), None),
    "staleness": (lambda: StalenessBoundTrigger(1), 1),
}


def lc_none() -> ClientLifecycle:
    return ClientLifecycle()


def lc_droprejoin() -> ClientLifecycle:
    """Client 2 drops mid-window 0 (cancelling its in-flight plan-0
    arrival on every grid), rejoins before plan 2 would dispatch."""
    return ClientLifecycle([
        LifecycleEvent(time=0.4, kind="dropout", client=2),
        LifecycleEvent(time=1.6, kind="rejoin", client=2),
    ])


def lc_join() -> ClientLifecycle:
    """A fourth client joins mid-window 0 and is dispatched from plan 1."""
    return ClientLifecycle([
        LifecycleEvent(time=0.6, kind="join", client=NUM_CLIENTS,
                       rank=8, shard=np.arange(2)),
    ])


LIFECYCLES = {"none": lc_none, "droprejoin": lc_droprejoin, "join": lc_join}


def build_scenarios(fast: bool):
    scenarios = []
    for trig_name, (trig, bound) in sorted(TRIGGERS.items()):
        for lc_name, lc in sorted(LIFECYCLES.items()):
            if fast and lc_name == "join":
                continue
            sym = lc_name == "none"
            scenarios.append(Scenario(
                name=f"protocol/{trig_name}/{lc_name}",
                num_clients=NUM_CLIENTS, num_plans=2,
                trigger_fn=trig, lifecycle_fn=lc,
                grid=GRID_FAST if fast else GRID_FULL,
                n_k=N_K_SYM if sym else N_K_HET, ranks=RANKS,
                staleness_bound=bound,
                symmetric=((0, 2),) if sym else ()))
        if not fast:
            # depth ladder: three overlapping plans on a coarser grid
            scenarios.append(Scenario(
                name=f"protocol/{trig_name}/none-3plan",
                num_clients=NUM_CLIENTS, num_plans=3,
                trigger_fn=trig, lifecycle_fn=lc_none, grid=GRID_LADDER,
                n_k=N_K_SYM, ranks=RANKS, staleness_bound=bound,
                symmetric=((0, 2),)))
    return scenarios


def _protocol_sweep(report: AuditReport, fast: bool, verbose: bool) -> None:
    for sc in build_scenarios(fast):
        findings, stats, _ = check_scenario(sc)
        audit = ProgramAudit(sc.name, "protocol", findings, stats.to_json())
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings[:10]:
                print(f"  {f}")
        s = stats.to_json()
        print(f"[prot] {sc.name:32s} {'ok' if audit.ok else 'FAIL'} "
              f"(schedules={s['unique_schedules']}/{s['assignments']}, "
              f"fires={s['fires']}, cuts={s['replays']})")


def _protocol_controls(report: AuditReport) -> None:
    """Injected protocol bugs on reduced grids: each invariant's tripwire
    must be live (a sweep whose rules cannot fail proves nothing)."""
    small = Scenario(name="control/protocol", num_clients=NUM_CLIENTS,
                     num_plans=2, trigger_fn=lambda: CountTrigger(3),
                     lifecycle_fn=lc_none, grid=GRID_FAST,
                     n_k=N_K_HET, ranks=RANKS)
    drop = Scenario(name="control/protocol-drop", num_clients=NUM_CLIENTS,
                    num_plans=2, trigger_fn=lambda: CountTrigger(2),
                    lifecycle_fn=lc_droprejoin, grid=GRID_FAST,
                    n_k=N_K_HET, ranks=RANKS)
    stale = Scenario(name="control/protocol-stale", num_clients=NUM_CLIENTS,
                     num_plans=2,
                     trigger_fn=lambda: StalenessBoundTrigger(2),
                     lifecycle_fn=lc_none, grid=GRID_FAST,
                     n_k=N_K_HET, ranks=RANKS, staleness_bound=0)

    report.run_control(
        "double-fire", "proto-exactly-once",
        lambda: check_scenario(small, replay=False,
                               sched_cls=DoubleConsumeScheduler)[0],
        "scheduler re-delivering consumed arrivals")
    report.run_control(
        "cancelled-delivery", "proto-cancelled-consumed",
        lambda: check_scenario(drop, replay=False,
                               sched_cls=CancelledDeliveryScheduler)[0],
        "scheduler delivering dropout-cancelled arrivals")
    report.run_control(
        "ghost-weight-leak", "proto-ghost-weight",
        lambda: check_scenario(drop, replay=False, break_present=True)[0],
        "aggregation ignoring the present mask")
    report.run_control(
        "torn-snapshot", "proto-replay-divergence",
        lambda: check_scenario(small, corrupt_replay=True)[0],
        "checkpoint snapshot corrupted before replay")
    report.run_control(
        "understated-staleness-bound", "proto-staleness-bound",
        lambda: check_scenario(stale, replay=False)[0],
        "trigger bound 2 vs declared bound 0")


def _rng_flow_sweep(report: AuditReport, verbose: bool) -> None:
    import jax
    import jax.numpy as jnp
    from repro.models.layers.dense import dense_init, lora_init
    from repro.models.layers.mlp import mlp_init

    key = jax.random.PRNGKey(0)
    rows = [
        ("rng-flow/dense_init",
         lambda k: dense_init(k, 16, 24, lora_rank=4), key),
        ("rng-flow/lora_init",
         lambda k: lora_init(k, 16, 24, 4), key),
        ("rng-flow/mlp_init",
         lambda k: mlp_init(k, 16, 32, "swiglu",
                            lora_ranks={"up_proj": 4, "down_proj": 4,
                                        "gate_proj": 4}), key),
    ]
    for name, fn, arg in rows:
        findings, stats = rng_lint.lint_key_flow(name, fn, arg)
        audit = ProgramAudit(name, "rng-flow", findings, stats)
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        print(f"[flow] {name:32s} {'ok' if audit.ok else 'FAIL'} "
              f"(keys={stats['keys']}, draws={stats['consumptions']})")

    report.run_control(
        "injected-key-reuse", "rng-key-reuse",
        lambda: rng_lint.lint_key_flow("control/key-reuse",
                                       rng_lint.broken_key_reuse,
                                       jax.random.PRNGKey(0))[0],
        "one key consumed by normal AND uniform")


# modules active while the virtual clock runs (launch/ CLIs time their own
# wall-clock phases and are off the round path by construction)
ROUND_PATH_FILES = (
    "src/repro/federation/events.py",
    "src/repro/federation/server.py",
    "src/repro/federation/topology.py",
    "src/repro/federation/experiment.py",
    "src/repro/federation/transport.py",
    "src/repro/core/aggregation.py",
    "src/repro/data/traces.py",
    "src/repro/checkpointing/checkpoint.py",
    "src/repro/analysis/protocol.py",
)

# serving-path sources: the hot-swap/decode loop must be as deterministic as
# the round path (the serve CLI's wall-phase prints carry explicit waivers)
SERVING_PATH_FILES = (
    "src/repro/serving/adapter_store.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/scheduler.py",
    "src/repro/launch/serve.py",
)


def _rng_host_sweep(report: AuditReport, verbose: bool) -> None:
    for path in ROUND_PATH_FILES + SERVING_PATH_FILES:
        with open(path) as f:
            source = f.read()
        name = f"rng-host/{path.split('src/repro/')[-1]}"
        findings, stats = rng_lint.lint_host_source(name, source)
        audit = ProgramAudit(name, "rng-host", findings, stats)
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        print(f"[host] {name:44s} {'ok' if audit.ok else 'FAIL'}")

    for ctl_name, rule, src, detail in [
            ("injected-host-clock", "rng-host-clock",
             rng_lint.BROKEN_HOST_CLOCK, "time.time() on the round path"),
            ("unseeded-default-rng", "rng-unseeded-default-rng",
             rng_lint.BROKEN_UNSEEDED, "np.random.default_rng() bare"),
            ("seed-collision", "rng-seed-collision",
             rng_lint.BROKEN_SEED_COLLISION,
             "two SeedSequence([seed, client]) sites"),
            ("set-order-iteration", "rng-order-sensitive-iteration",
             rng_lint.BROKEN_SET_ITERATION,
             "aggregation input built from set(clients)"),
            ("host-key-reuse", "rng-host-key-reuse",
             rng_lint.BROKEN_HOST_KEY_REUSE,
             "one PRNGKey feeding init AND randint")]:
        report.run_control(
            ctl_name, rule,
            lambda s=src, n=ctl_name:
                rng_lint.lint_host_source(f"control/{n}", s)[0],
            detail)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="AUDIT_protocol.json")
    ap.add_argument("--fast", action="store_true",
                    help="bounded smoke scope: 2-value grids, no 3-plan "
                         "ladder, no mid-run-join scenario")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    report = AuditReport(matrix={
        "clients": NUM_CLIENTS, "ranks": list(RANKS),
        "n_k": {"het": list(N_K_HET), "sym": list(N_K_SYM)},
        "triggers": sorted(TRIGGERS),
        "lifecycles": sorted(LIFECYCLES),
        "grid": list(GRID_FAST if args.fast else GRID_FULL),
        "scope": "fast" if args.fast else "full",
        "round_path_files": list(ROUND_PATH_FILES),
        "serving_path_files": list(SERVING_PATH_FILES),
    })

    _protocol_sweep(report, args.fast, args.verbose)
    _protocol_controls(report)
    _rng_flow_sweep(report, args.verbose)
    _rng_host_sweep(report, args.verbose)

    report.write(args.out)
    s = report.summary()
    print(f"[vrfy] {s['programs']} programs, {s['errors']} errors, "
          f"{s['controls']} controls ({len(s['controls_failed'])} dead) "
          f"-> {args.out}")
    if not report.ok:
        for p in report.failed_programs:
            print(f"[vrfy] FAIL {p.program}: "
                  + "; ".join(str(f) for f in p.errors[:3]))
        for name in report.failed_controls:
            ctl = report.controls[name]
            why = ctl.error or "did not trip"
            print(f"[vrfy] DEAD CONTROL {name}: rule {ctl.rule} {why}")
        return 1
    print("[vrfy] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
