#!/usr/bin/env python
"""Program-audit sweep (DESIGN.md §8): run the four analysis passes over
the engine x backend x METHODS matrix and write the tracked
``AUDIT_program_lint.json``.

    PYTHONPATH=src python tools/lint_programs.py [--out PATH]
        [--skip-dispatch] [--vmem-target v5e] [--verbose]

Matrix (small shapes -- the rules are scale-free, chosen so every legal
low-rank stack stays strictly below the (d, n) materialization bar):

  engines   sequential (_stacked_core), batched (_grouped_core), async
            (the same grouped program at pipeline_depth x M clients),
            event (the same grouped program the fire path dispatches, a
            present-mask is omega DATA), sharded (sharded_grouped_fn on
            the FL mesh)
  methods   avg family (fedavg / hetlora / ffa / flora) once per engine
            (backend-independent); SVD family (flexlora / raflora) x
            {dense, factored, kernel}
  passes    hlo_lint on every compiled program; jaxpr_lint on the round-
            path entry points; pallas_lint over the kernel registry;
            dispatch_audit over a multi-round federated run per engine

All lowering goes through the shared ``repro.analysis.lowering`` cache:
each of the matrix programs is compiled ONCE per process and its parsed
payload is reused by the lint pass, the collective-parity pass and (when
run in the same process, ``tools/certify_scaling.py --with-lint``) the
complexity certifier.

Positive controls (deliberately broken programs; the sweep FAILS if any
control does NOT trip -- dead tripwires are treated as regressions, and
a control pass that RAISES is recorded as failed the same way):
dense-backend materialization, an injected ``jax.debug.callback``, a
compiled host-callback custom-call, a bf16 program with f32 upcasts, an
oversized fabricated BlockSpec, and a shape-varying round sequence.

Exit status: 0 sweep green + all controls tripped, 1 otherwise, 2 on
usage errors. ``tools/ci.sh lint`` runs this under a forced 8-device CPU
platform so the sharded rows exercise real collectives.
"""
from __future__ import annotations

import argparse
import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np

# sweep shapes: chosen so M_max * P * r_max < min(d, n) -- every legal
# stack is then strictly below the d*n materialization bar (see hlo_lint)
D, N, R_MAX = 160, 192, 8
RANK_LEVELS = (4, 8)
M_PER_GROUP = 2                 # clients per rank group (non-sharded rows)
P_BUCKET = 2                    # adapters per bucket (grouped rows)
ASYNC_DEPTH = 2
DISPATCH_ROUNDS, DISPATCH_WARMUP = 6, 2
MAX_EAGER_PER_ROUND = 8         # measured ~1; generous headroom

from repro.analysis.lowering import (AVG_METHODS, BACKENDS, ENGINES,
                                     ProgramPoint, SVD_METHODS,
                                     _grouped_avals, cache_info,
                                     lower_program)

_SDS = jax.ShapeDtypeStruct


def _f32(*shape):
    return _SDS(shape, jnp.float32)


def _pad_lane(x: int) -> int:
    """Lane-padded extent: the kernel backend pads d / n up to the 128-lane
    tile (kernels/ops.py ``_tile_block``), so its arrays are compared at
    padded scale; the exact (D, N) trailing-dims check still catches a
    dense dW."""
    return -(-x // 128) * 128


def _res_leaves(res):
    """AggregationResult -> tuple of array leaves (make_jaxpr cannot
    return the dataclass itself)."""
    return tuple(x for x in (res.b_g, res.a_g, res.sigma, res.merge_delta)
                 if x is not None)


def _lint_point(engine: str, method: str, backend: str) -> ProgramPoint:
    """The PR-6 lint matrix shapes as a cacheable ProgramPoint."""
    return ProgramPoint(
        engine=engine, method=method, backend=backend, d=D, n=N,
        rank_levels=RANK_LEVELS, m_per_group=M_PER_GROUP,
        p_bucket=P_BUCKET, depth=ASYNC_DEPTH if engine == "async" else 1,
        shards=0)


def _hlo_meta(method: str, backend: str) -> dict:
    """Per-row rule thresholds. Materialization is armed for the SVD
    family (flora's merge_delta is dense BY DESIGN; avg methods never
    form products); non-sharded programs get a zero collective budget."""
    meta = {"max_collective_count": 0, "max_collective_bytes": 0}
    if method in SVD_METHODS:
        # kernel rows are measured at 128-lane padded scale (the Pallas
        # wrappers pad d/n to tile multiples); dense dW still trips via
        # the exact trailing-dims check
        elems = (_pad_lane(D) * _pad_lane(N) if backend == "kernel"
                 else D * N)
        meta.update(forbid_elems=elems, forbid_dims=(D, N))
    return meta


def _sharded_meta(method: str, backend: str, n_dev: int) -> dict:
    """Collective budgets for the sharded rows: exact expected result-
    buffer bytes of the per-bucket psums x1.5 slack (DESIGN.md §5)."""
    if method in ("fedavg", "hetlora"):
        exact = 4 * (D * R_MAX + R_MAX * N)
    elif method == "ffa":
        exact = 4 * R_MAX * N
    elif method in ("flora",) or backend == "dense":
        exact = 4 * D * N
    else:                       # factored/kernel: zero-scattered stacks
        width = 2 * 8 * n_dev   # 2 groups x r8-padded width x shards
        exact = 4 * (D * width + width * N)
    meta = {"max_collective_count": 2,
            "max_collective_bytes": int(1.5 * exact)}
    if method in SVD_METHODS and backend != "dense":
        # the kernel backend pads d/n to the 128-lane tile and carries
        # zero-scattered stacks of width S*W -- compare at padded scale
        elems = (_pad_lane(D) * _pad_lane(N) if backend == "kernel"
                 else D * N)
        meta.update(forbid_elems=elems, forbid_dims=(D, N))
    return meta


def _hlo_sweep(report, verbose):
    from repro.analysis import hlo_lint
    from repro.analysis.report import ProgramAudit
    n_dev = jax.device_count()
    rows = []
    for engine in ENGINES:
        for method in AVG_METHODS:
            rows.append((engine, method, "-"))
        for method in SVD_METHODS:
            for backend in BACKENDS:
                rows.append((engine, method, backend))
    dense_controls = []
    parity_stats = {}
    for engine, method, backend in rows:
        name = f"{engine}/{method}/{backend}"
        be = backend if backend != "-" else "factored"
        lowered = lower_program(_lint_point(engine, method, be))
        meta = (_sharded_meta(method, be, n_dev) if engine == "sharded"
                else _hlo_meta(method, be))
        findings, payload = hlo_lint.lint_hlo(lowered.text, name, meta,
                                              payload=lowered.payload)
        stats = {"collective_counts": {k: int(v) for k, v in
                                       payload.stats.collective_counts
                                       .items()},
                 "collective_bytes": int(
                     payload.stats.total_collective_bytes)}
        if method in SVD_METHODS and backend in ("factored", "kernel"):
            parity_stats[(engine, method, backend)] = payload.stats
        if method in SVD_METHODS and backend == "dense":
            # the dense backend MUST trip the materialization rule: it is
            # the standing positive control that the tripwire is live
            mat = [f for f in findings if f.rule == "hlo-materialization"]
            dense_controls.extend(mat)
            findings = [f for f in findings
                        if f.rule != "hlo-materialization"]
            stats["expected_materialization_hits"] = len(mat)
        audit = ProgramAudit(name, "hlo", findings, stats)
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        print(f"[hlo ] {name:28s} "
              f"{'ok' if audit.ok else 'FAIL'} "
              f"(coll={stats['collective_bytes']}B)")
    report.add_control(
        "dense-materialization", "hlo-materialization", dense_controls,
        f"{len(dense_controls)} (d, n)-scale arrays across dense rows")
    # kernel == factored collective parity per engine (one source of truth
    # for the byte accounting fl_dryrun used to duplicate) -- runs on the
    # CACHED walker stats, no re-parse
    parity = []
    for engine in ENGINES:
        for method in SVD_METHODS:
            parity.extend(hlo_lint.collective_parity_stats(
                parity_stats[(engine, method, "factored")],
                parity_stats[(engine, method, "kernel")],
                label_a="factored", label_b="kernel",
                program=f"{engine}/{method}/parity"))
    report.add(ProgramAudit("parity/kernel-vs-factored", "hlo", parity,
                            {"pairs": 10}))
    print(f"[hlo ] parity kernel==factored: "
          f"{'ok' if not parity else 'FAIL'}")


def _jaxpr_entry_points(exp):
    """(name, jaxpr) for the round-path entry points of ISSUE 6."""
    from repro.analysis import jaxpr_lint
    from repro.core.svd import svd_realloc_gram
    server = exp.server
    out = []

    # client.train_group_masked: the un-jitted masked group body
    b = server.batch_fn(0, np.random.default_rng(0))[0]
    stacks = jax.tree.map(lambda x: np.stack([x, x])[None], b)
    r_max = server.model.lora.r_max
    mask = np.ones((2, r_max), np.float32)
    scales = np.ones((2,), np.float32)
    run = server.trainer._masked_run_fn(1)
    out.append(("jaxpr/train_group_masked", jaxpr_lint.trace(
        run, server.global_lora, server.base, stacks, np.float32(1e-3),
        mask, scales)))

    # Aggregator.aggregate_stack / aggregate_grouped (+ the event-engine
    # fire path: aggregate_grouped with a present mask)
    agg = server.aggregator
    m = M_PER_GROUP * len(RANK_LEVELS)
    ranks = [r for r in RANK_LEVELS for _ in range(M_PER_GROUP)]
    n_k = [10.0] * m
    bs, as_ = _f32(m, D, R_MAX), _f32(m, R_MAX, N)
    out.append(("jaxpr/aggregate_stack", jaxpr_lint.trace(
        lambda b_, a_: _res_leaves(
            agg.aggregate_stack(b_, a_, ranks, n_k)),
        bs, as_)))
    gbs_, gas_, _, gbs, gas, _ = _grouped_avals(
        _lint_point("batched", "raflora", "factored"), False)
    out.append(("jaxpr/aggregate_grouped", jaxpr_lint.trace(
        lambda b_, a_: _res_leaves(
            agg.aggregate_grouped(b_, a_, ranks, n_k, global_bs=gbs,
                                  global_as=gas)),
        gbs_, gas_)))
    present = [True] * (m - 1) + [False]
    out.append(("jaxpr/event_fire", jaxpr_lint.trace(
        lambda b_, a_: _res_leaves(
            agg.aggregate_grouped(b_, a_, ranks, n_k, present=present)),
        gbs_, gas_)))

    # svd_realloc_gram: the kernel backend's realloc core
    width = 4 * 8
    out.append(("jaxpr/svd_realloc_gram", jaxpr_lint.trace(
        functools.partial(svd_realloc_gram, r_max=R_MAX),
        _f32(D, width), _f32(width, N), _f32(width, width),
        _f32(width, width))))
    return out


def _jaxpr_sweep(report, exp, verbose):
    from repro.analysis import jaxpr_lint
    from repro.analysis.report import ProgramAudit
    for name, jx in _jaxpr_entry_points(exp):
        findings = jaxpr_lint.lint_jaxpr(jx, name)
        audit = ProgramAudit(name, "jaxpr", findings,
                             jaxpr_lint.jaxpr_stats(jx))
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        print(f"[jxpr] {name:28s} {'ok' if audit.ok else 'FAIL'}")

    # control: an injected debug callback on the round path must trip
    def poisoned_pass():
        def poisoned(x):
            jax.debug.callback(lambda v: None, x)
            return x * 2.0
        return jaxpr_lint.lint_jaxpr(jaxpr_lint.trace(poisoned, _f32(4)),
                                     "control/jaxpr-callback")

    report.run_control("injected-debug-callback", "jaxpr-callback",
                       poisoned_pass)


def _pallas_sweep(report, verbose, vmem_meta):
    from repro.analysis import pallas_lint
    from repro.analysis.report import ProgramAudit
    progs = pallas_lint.collect_registry()
    findings = pallas_lint.lint_kernels(progs, "pallas/registry",
                                        vmem_meta)
    stats = {
        "kernels": sorted({r.name for r in progs.records}),
        "launches": len(progs.records),
        "probes": [{"name": p.name, "ok": p.ok, "detail": p.detail}
                   for p in progs.probes],
        "max_vmem_bytes": max(
            (pallas_lint.estimate_vmem(r) for r in progs.records),
            default=0),
        "vmem_budget_bytes": pallas_lint.vmem_budget(vmem_meta),
    }
    audit = ProgramAudit("pallas/registry", "pallas", findings, stats)
    report.add(audit)
    if verbose or not audit.ok:
        for f in findings:
            print(f"  {f}")
    print(f"[plas] registry: {len(progs.records)} launches from "
          f"{len(stats['kernels'])} kernels, max VMEM "
          f"{stats['max_vmem_bytes'] / 2 ** 20:.2f} MiB "
          f"{'ok' if audit.ok else 'FAIL'}")

    def oversized_pass():
        return pallas_lint.lint_kernels(pallas_lint.oversized_control(),
                                        "control/pallas-oversized",
                                        vmem_meta)

    report.run_control("oversized-blockspec", "pallas-vmem-budget",
                       oversized_pass)
    report.run_control("blockspec-out-of-bounds", "pallas-grid-blockspec",
                       oversized_pass)


def _build_tiny_experiment(engine: str, depth: int = 1):
    from repro.federation.experiment import build_experiment
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": DISPATCH_ROUNDS + 2, "num_clients": 6,
                      "participation": 1.0},
        lora_overrides={"rank_levels": RANK_LEVELS,
                        "rank_probs": (0.5, 0.5)},
        num_classes=4, d_model=32, samples_per_class=20,
        batches_per_round=1, backend="kernel", round_engine=engine,
        pipeline_depth=depth)


def _dispatch_sweep(report, exp_batched, verbose):
    from repro.analysis import dispatch_audit
    from repro.analysis.report import ProgramAudit
    meta = {"warmup": DISPATCH_WARMUP,
            "max_eager_per_phase": MAX_EAGER_PER_ROUND}
    engines = [("batched", exp_batched),
               ("async", _build_tiny_experiment("async", ASYNC_DEPTH))]
    for engine, exp in engines:
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(DISPATCH_ROUNDS):
                exp.server.run_round()
                mon.mark(f"round{r}")
        name = f"dispatch/{engine}"
        findings = dispatch_audit.lint_dispatch(mon, name, meta)
        audit = ProgramAudit(name, "dispatch", findings, mon.stats())
        report.add(audit)
        if verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        steady = mon.phases[DISPATCH_WARMUP:]
        print(f"[disp] {name}: {DISPATCH_ROUNDS} rounds, steady "
              f"traces={sum(p.traces for p in steady)} "
              f"compiles={sum(p.compiles for p in steady)} "
              f"eager<={max((p.eager_binds for p in steady), default=0)} "
              f"{'ok' if audit.ok else 'FAIL'}")

    # control: shape-varying steady-state rounds MUST trip the recompiler
    def shape_varying_pass():
        f = jax.jit(lambda x: (x * 2.0).sum())
        mon = dispatch_audit.DispatchMonitor()
        with mon:
            for r in range(4):
                np.asarray(f(jnp.ones((8 + r,))))
                mon.mark(f"round{r}")
        return dispatch_audit.lint_dispatch(mon, "control/shape-varying",
                                            {"warmup": 1})

    report.run_control("shape-varying-round",
                       "dispatch-steady-state-recompile",
                       shape_varying_pass)


def _hlo_controls(report):
    """Compiled-program controls for the remaining HLO rules: a program
    with a host callback and a bf16 program with f32 upcasts."""
    from repro.analysis import hlo_lint

    def callback_pass():
        def with_callback(x):
            return jax.pure_callback(
                lambda v: np.asarray(v),
                jax.ShapeDtypeStruct(x.shape, x.dtype), x) + 1.0

        text = jax.jit(with_callback).lower(_f32(8)).compile().as_text()
        findings, _ = hlo_lint.lint_hlo(text, "control/host-callback")
        return findings

    report.run_control("compiled-host-callback", "hlo-host-transfer",
                       callback_pass)

    def bf16_pass():
        def bf16_matmul(x, w):
            return x @ w

        b = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
        text = jax.jit(bf16_matmul).lower(b, b).compile().as_text()
        findings, _ = hlo_lint.lint_hlo(
            text, "control/bf16-upcast", {"bf16_min_elems": 256 * 256})
        return findings

    report.run_control("bf16-upcast", "hlo-dtype-upcast", bf16_pass)


def main(argv=None) -> int:
    from repro.analysis.pallas_lint import DEFAULT_VMEM_TARGET, \
        VMEM_BUDGETS

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="AUDIT_program_lint.json")
    ap.add_argument("--skip-dispatch", action="store_true",
                    help="skip the multi-round dispatch audit (the only "
                         "pass that runs real rounds)")
    ap.add_argument("--vmem-target", default=DEFAULT_VMEM_TARGET,
                    choices=sorted(VMEM_BUDGETS),
                    help="TPU generation whose VMEM budget gates the "
                         "pallas pass (default %(default)s)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro.analysis.report import AuditReport
    report = AuditReport(matrix={
        "d": D, "n": N, "r_max": R_MAX, "rank_levels": list(RANK_LEVELS),
        "clients_per_group": M_PER_GROUP, "bucket_adapters": P_BUCKET,
        "async_depth": ASYNC_DEPTH, "devices": jax.device_count(),
        "engines": list(ENGINES),
        "avg_methods": list(AVG_METHODS), "svd_methods": list(SVD_METHODS),
        "backends": list(BACKENDS),
        "vmem_target": args.vmem_target,
        "dispatch": {"rounds": DISPATCH_ROUNDS, "warmup": DISPATCH_WARMUP,
                     "max_eager_per_phase": MAX_EAGER_PER_ROUND},
    })

    _hlo_sweep(report, args.verbose)
    _hlo_controls(report)
    exp = _build_tiny_experiment("batched")
    _jaxpr_sweep(report, exp, args.verbose)
    _pallas_sweep(report, args.verbose, {"vmem_target": args.vmem_target})
    if not args.skip_dispatch:
        _dispatch_sweep(report, exp, args.verbose)

    report.write(args.out)
    s = report.summary()
    print(f"[lint] {s['programs']} programs, {s['errors']} errors, "
          f"{s['controls']} controls "
          f"({len(s['controls_failed'])} dead), "
          f"{cache_info()['entries']} unique lowerings -> {args.out}")
    if not report.ok:
        for p in report.failed_programs:
            print(f"[lint] FAIL {p.program}: "
                  + "; ".join(str(f) for f in p.errors[:3]))
        for name in report.failed_controls:
            ctl = report.controls[name]
            why = ctl.error or "did not trip"
            print(f"[lint] DEAD CONTROL {name}: rule {ctl.rule} {why}")
        return 1
    print("[lint] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
