#!/usr/bin/env python
"""Round-latency trend guard (ISSUE 5 satellite): compare a FRESH
``bench_round_latency`` artifact against the tracked baseline and fail on
a >25% per-round regression of any existing engine x backend row.

    python tools/bench_trend.py --baseline OLD.json --fresh NEW.json \
        [--threshold 1.25] [--absolute]

Rows compared (each a seconds-per-round statistic):

  engine/sequential, engine/batched        top-level study
  sharded/<shards>                         ``sharded`` study
  async/<depth-or-batched>                 ``async`` study
  kernel/<config>                          ``kernel_backend`` study
  transport/<mode>                         ``transport`` study (ISSUE 10)

Defenses against shared-CPU noise (which drifts 2-3x between sessions
and is one-sided -- contention only ADDS time):

* TWO statistics are compared per row -- the MEDIAN and the MIN over the
  study's interleaved timed blocks (``per_round_s``; artifacts without
  raw blocks fall back to ``median_s`` for both). A row fails only when
  BOTH statistics regress past the threshold: a genuine slowdown shows
  up in every quantile, while a load spike inflates the median of one
  run or starves one section's min, but rarely corrupts both statistics
  of the same interleaved sample;
* every row is NORMALIZED by its own run's ``engine/batched`` row (the
  one row present in every artifact since PR 1), so uniform machine
  drift cancels and the gate measures each engine's cost RELATIVE to the
  batched reference -- exactly the property the engine studies track.
  The reference row itself would be ungateable under its own
  normalization (always 1.0x -- a uniform slowdown of everything would
  pass), so ``engine/batched`` is gated in ABSOLUTE seconds instead,
  still under the median-AND-min rule but at a WIDER threshold
  (``--ref-threshold``, default 3.0x): absolute cross-session numbers
  legitimately drift 2-3x on this container, so the reference gate can
  only catch catastrophic uniform regressions, not 25% ones -- that is
  the honest capability, and it is documented rather than flaky.
  ``--absolute`` compares every row in raw seconds at the strict
  threshold (meaningful on a quiet, pinned box).

Rows present only in the fresh run are reported as new; rows only in the
baseline (a study that was not rerun) are skipped. ``event`` rows are
virtual-time simulation outcomes -- exactly reproducible and appended
across runs -- gated per (trigger, straggler_frac) on the LATEST
``virtual_time_to_target_energy`` of each side at the same wide
catastrophic-only bar as the batched reference row (``--ref-threshold``):
virtual time is deterministic, so only a structural scheduler regression
moves it, but small drifts are expected when trigger constants are
intentionally retuned. A fresh ``null`` (target energy never reached)
against a finite baseline is always a regression.

Serving rows (``--serve-baseline``/``--serve-fresh``, the tracked
``BENCH_serve_latency.json``) are VIRTUAL-time continuous-batching
outcomes -- deterministic like the event rows -- gated per
(batch, adapters, swap_every) cell on ``virtual_p95_s`` (up = worse) and
``virtual_throughput_tok_per_s`` (down = worse) at the same wide
catastrophic-only bar: only a structural scheduler/engine regression can
move them, but intentional cost-constant retunes shift every cell a
little. Wall-clock context fields are never gated.

Exit status: 0 clean, 1 regression, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import sys


def _section_rows(out: dict, section: dict, prefix: str) -> None:
    per_block = section.get("per_round_s") or {}
    for k, v in (section.get("median_s") or {}).items():
        blocks = per_block.get(str(k)) or per_block.get(k)
        med = float(v)
        out[f"{prefix}/{k}"] = (med, float(min(blocks)) if blocks else med)


def _rows(artifact: dict) -> dict:
    """{row: (median_s, min_s)} over every engine study in the artifact."""
    out = {}
    _section_rows(out, artifact, "engine")
    for key, prefix in (("sharded", "sharded"), ("async", "async"),
                        ("kernel_backend", "kernel"),
                        ("transport", "transport")):
        _section_rows(out, artifact.get(key) or {}, prefix)
    return out


def _event_latest(artifact: dict) -> dict:
    """{(trigger, straggler_frac): latest row} -- rows are append-only, so
    the last row per key is the current scheduler's outcome."""
    out = {}
    for row in (artifact.get("event") or {}).get("rows") or []:
        out[(row.get("trigger"), row.get("straggler_frac"))] = row
    return out


def _gate_events(baseline: dict, fresh: dict, ref_threshold: float,
                 regressions: list) -> None:
    """Gate event-mode rows on virtual_time_to_target_energy at the wide
    catastrophic-only bar (None = never reached target = infinity)."""
    base_ev, fresh_ev = _event_latest(baseline), _event_latest(fresh)
    if not fresh_ev:
        return
    print(f"[bench-trend] {len(fresh_ev)} event-mode rows (virtual time, "
          f"bar {ref_threshold:.1f}x)")
    for key in sorted(fresh_ev, key=str):
        trigger, frac = key
        row = fresh_ev[key]
        f_vt = row.get("virtual_time_to_target_energy")
        name = f"event/{trigger}/straggler={frac}"
        if key not in base_ev:
            print(f"  NEW    {name}: vt_to_target="
                  f"{'n/a' if f_vt is None else f_vt}")
            continue
        b_vt = base_ev[key].get("virtual_time_to_target_energy")
        b = float("inf") if b_vt is None else float(b_vt)
        f = float("inf") if f_vt is None else float(f_vt)
        if f <= b or b == float("inf"):   # faster, equal, or both n/a
            ratio, regressed = (1.0 if f == b else f / b), False
        else:
            ratio = f / b                 # inf when fresh stopped reaching
            regressed = ratio > ref_threshold
        flag = "REGRESS" if regressed else "ok"
        print(f"  {flag:7s}{name}: vt {ratio:.2f}x "
              f"(base {'n/a' if b_vt is None else b_vt}, "
              f"fresh {'n/a' if f_vt is None else f_vt}, "
              f"aggs={row.get('aggregations')})")
        if regressed:
            regressions.append((name, ratio))


def _serve_rows(artifact: dict) -> dict:
    """{(batch, adapters, swap_every): row} from BENCH_serve_latency."""
    return {(r.get("batch"), r.get("adapters"), r.get("swap_every")): r
            for r in artifact.get("rows") or []}


def gate_serve(baseline: dict, fresh: dict, ref_threshold: float,
               regressions: list) -> None:
    """Gate serving cells on virtual p95 latency and token throughput at
    the wide catastrophic-only bar (virtual time is deterministic)."""
    base_sv, fresh_sv = _serve_rows(baseline), _serve_rows(fresh)
    if not fresh_sv:
        return
    print(f"[bench-trend] {len(fresh_sv)} serving cells (virtual time, "
          f"bar {ref_threshold:.1f}x)")
    for key in sorted(fresh_sv, key=str):
        batch, adapters, swap = key
        row = fresh_sv[key]
        name = f"serve/b{batch}_a{adapters}_sw{swap}"
        if key not in base_sv:
            print(f"  NEW    {name}: p95={row.get('virtual_p95_s'):.3f}s")
            continue
        base = base_sv[key]
        ratios = []
        for field, worse_up in (("virtual_p95_s", True),
                                ("virtual_throughput_tok_per_s", False)):
            b, f = base.get(field), row.get(field)
            if not b or not f:
                continue
            ratios.append((field, f / b if worse_up else b / f))
        regressed = any(r > ref_threshold for _, r in ratios)
        flag = "REGRESS" if regressed else "ok"
        print(f"  {flag:7s}{name}: "
              + " ".join(f"{fld}={r:.2f}x" for fld, r in ratios))
        if regressed:
            regressions.append(
                (name, max(r for _, r in ratios)))


def compare(baseline: dict, fresh: dict, *, threshold: float,
            absolute: bool, ref_threshold: float = 3.0) -> int:
    base_rows, fresh_rows = _rows(baseline), _rows(fresh)
    ref_key = "engine/batched"
    norm = not absolute
    if norm and (ref_key not in base_rows or ref_key not in fresh_rows):
        print(f"[bench-trend] WARNING: {ref_key} missing -- "
              "falling back to absolute seconds")
        norm = False
    b_ref = base_rows.get(ref_key, (1.0, 1.0)) if norm else (1.0, 1.0)
    f_ref = fresh_rows.get(ref_key, (1.0, 1.0)) if norm else (1.0, 1.0)

    regressions = []
    mode = "normalized-to-batched" if norm else "absolute"
    print(f"[bench-trend] comparing {len(fresh_rows)} fresh rows "
          f"({mode}, threshold {threshold:.2f}x on median AND min)")
    for key in sorted(fresh_rows):
        if key not in base_rows:
            print(f"  NEW    {key}: {fresh_rows[key][0] * 1e3:.2f} ms")
            continue
        # the normalization reference is always 1.0x against itself, which
        # would let a uniform slowdown through -- gate it absolutely, at
        # the wide catastrophic-only threshold (cross-session absolute
        # drift is 2-3x on shared machines)
        absolute_row = not norm or key == ref_key
        bar = ref_threshold if (absolute_row and norm) else threshold
        ratios = []
        for stat in (0, 1):                       # (median, min)
            b = base_rows[key][stat] / (1.0 if absolute_row
                                        else b_ref[stat])
            f = fresh_rows[key][stat] / (1.0 if absolute_row
                                         else f_ref[stat])
            ratios.append(f / b if b > 0 else float("inf"))
        regressed = all(r > bar for r in ratios)
        flag = "REGRESS" if regressed else "ok"
        note = (f" (absolute, bar {bar:.1f}x)" if absolute_row and norm
                else "")
        print(f"  {flag:7s}{key}: median {ratios[0]:.2f}x "
              f"min {ratios[1]:.2f}x{note}")
        if regressed:
            regressions.append((key, min(ratios)))
    for key in sorted(set(base_rows) - set(fresh_rows)):
        print(f"  SKIP   {key}: not in fresh run")

    _gate_events(baseline, fresh, ref_threshold, regressions)

    if regressions:
        worst = max(regressions, key=lambda kv: kv[1])
        print(f"[bench-trend] FAIL: {len(regressions)} row(s) regressed "
              f">{(threshold - 1) * 100:.0f}% (worst {worst[0]} "
              f"{worst[1]:.2f}x)")
        return 1
    print("[bench-trend] OK: no per-round regression")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="tracked BENCH_round_latency.json snapshot")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced artifact")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail ratio (1.25 = >25%% per-round regression)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw seconds (no batched normalization)")
    ap.add_argument("--ref-threshold", type=float, default=3.0,
                    help="absolute fail ratio for the engine/batched "
                         "reference row in normalized mode (wide: "
                         "cross-session absolute drift is 2-3x)")
    ap.add_argument("--serve-baseline", default=None,
                    help="tracked BENCH_serve_latency.json snapshot "
                         "(optional; gated only when both serve paths "
                         "are given)")
    ap.add_argument("--serve-fresh", default=None,
                    help="freshly produced serving artifact")
    args = ap.parse_args(argv)
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[bench-trend] cannot load artifacts: {e}")
        return 2
    rc = compare(baseline, fresh, threshold=args.threshold,
                 absolute=args.absolute,
                 ref_threshold=args.ref_threshold)
    if args.serve_baseline and args.serve_fresh:
        try:
            with open(args.serve_baseline) as f:
                serve_base = json.load(f)
            with open(args.serve_fresh) as f:
                serve_fresh = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[bench-trend] cannot load serving artifacts: {e}")
            return 2
        serve_reg: list = []
        gate_serve(serve_base, serve_fresh, args.ref_threshold, serve_reg)
        if serve_reg:
            worst = max(serve_reg, key=lambda kv: kv[1])
            print(f"[bench-trend] FAIL: {len(serve_reg)} serving cell(s) "
                  f"regressed (worst {worst[0]} {worst[1]:.2f}x)")
            rc = max(rc, 1)
        else:
            print("[bench-trend] OK: no serving regression")
    return rc


if __name__ == "__main__":
    sys.exit(main())
