#!/usr/bin/env python
"""Complexity-certifier sweep (DESIGN.md §9): lower every engine x
backend x method program at a geometric ladder of problem sizes, fit
log-log scaling exponents per axis, gate them against the declared
contract catalog (``analysis/complexity.CONTRACTS``) and write the
tracked ``AUDIT_scaling.json``.

    PYTHONPATH=src python tools/certify_scaling.py [--out PATH] [--fast]
        [--vmem-target v5e] [--with-lint [--lint-out PATH]
        [--lint-skip-dispatch]]

Axes and ladders (geometric; sizes are 128-lane-aligned so the kernel
backend's pad-to-tile never bends a fit):

  dn        d = n = s together -- the axis that separates O(d*n) from
            O((d+n)R): dense slope ~2, factored/kernel ~1. All engines.
  d, n      single-axis ladders (batched engine rows).
  m         clients per rank group (batched + sharded rows).
  r         r_max via single-level rank_levels=(r,) (batched rows).
  shards    mesh size (sharded rows; needs the forced 8-device CPU
            platform, see tools/ci.sh).
  registry  registered-client count at FIXED cohort, measured as host
            counters over real tiny rounds (``analysis/host_cost``) on
            the batched AND event engines.
  (host) m  sampled-cohort ladder of the same host counters.

Every lowering goes through the shared ``analysis/lowering`` cache, so
the base point of each row is compiled once and reused by every axis
(and by the lint sweep when run in the same process via ``--with-lint``).

Positive controls (the sweep FAILS if any does NOT trip): the dense
backend must certify O(d*n) against the low-rank contracts
(``dense-dn-superlinear``), and an injected O(registry) host scan must
trip the registry contract (``host-registry-scan``). A control that
RAISES fails the report the same way (report.run_control).

Exit status: 0 all contracts hold + controls tripped, 1 otherwise, 2 on
usage errors. ``tools/ci.sh certify`` runs the full sweep; ``tools/ci.sh
lint-fast`` runs ``--fast --with-lint`` on reduced ladders for the smoke
tier.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

# ladders: lane-aligned dn/d/n; m/r geometric; the base point (first
# entry of each ladder) is shared across axes through the lowering cache
DN_LADDER = (128, 256, 512)
M_LADDER = (2, 4, 8)
R_LADDER = (8, 16)
SHARD_LADDER = (2, 4, 8)
HOST_K_LADDER = (1_000, 10_000, 100_000)
HOST_M_LADDER = (4, 8, 16)
HOST_NUM_CLIENTS = 32
HOST_ROUNDS, HOST_WARMUP = 3, 1
EVENT_ROUNDS, EVENT_WARMUP = 4, 2

FAST_DN_LADDER = (128, 256)
FAST_M_LADDER = (2, 4)
FAST_SHARD_LADDER = (2, 4)
FAST_HOST_K_LADDER = (1_000, 10_000)
FAST_HOST_M_LADDER = (4, 8)


def _device_rows(fast: bool):
    """(engine, method, backend_label) rows; '-' = avg family (lowered
    with the factored default, backend-independent)."""
    from repro.analysis.lowering import BACKENDS, ENGINES, SVD_METHODS
    engines = ("batched", "sharded") if fast else ENGINES
    svd = ("raflora",) if fast else SVD_METHODS
    avg = ("fedavg",) if fast else ("fedavg", "hetlora", "ffa", "flora")
    rows = []
    for engine in engines:
        for method in avg:
            rows.append((engine, method, "-"))
        for method in svd:
            for backend in BACKENDS:
                rows.append((engine, method, backend))
    return rows


def _measure_device_row(engine: str, method: str, label: str,
                        fast: bool):
    """ScalingRow of one program: lower at every ladder point of every
    axis that applies to its engine, extract the device cost vector."""
    from repro.analysis.complexity import Measurement, ScalingRow, \
        device_costs
    from repro.analysis.lowering import ProgramPoint, lower_program

    backend = "factored" if label == "-" else label
    depth = 2 if engine == "async" else 1
    base = ProgramPoint(engine=engine, method=method, backend=backend,
                        d=DN_LADDER[0], n=DN_LADDER[0], rank_levels=(8,),
                        m_per_group=M_LADDER[0], p_bucket=1, depth=depth,
                        shards=0)
    dn = FAST_DN_LADDER if fast else DN_LADDER
    ms = FAST_M_LADDER if fast else M_LADDER
    sh = FAST_SHARD_LADDER if fast else SHARD_LADDER

    meas = []

    def probe(axis, x, pt):
        meas.append(Measurement(axis, float(x),
                                device_costs(lower_program(pt))))

    for s in dn:
        probe("dn", s, base.scaled(d=s, n=s))
    if engine == "batched" and not fast:
        for s in dn[1:]:
            probe("d", s, base.scaled(d=s))
            probe("n", s, base.scaled(n=s))
        probe("d", dn[0], base)
        probe("n", dn[0], base)
    if engine == "batched":
        # the sharded engine has no cohort axis to measure: its stack
        # width is device-count-bound (one slot per shard), m_per_group
        # never reaches the lowered shapes
        for m in ms:
            probe("m", m * depth, base.scaled(m_per_group=m))
    if engine == "batched" and not fast:
        for r in R_LADDER:
            probe("r", r, base.scaled(rank_levels=(r,)))
    if engine == "sharded":
        for s in sh:
            probe("shards", s, base.scaled(shards=s))
    return ScalingRow(program=f"{engine}/{method}/{label}", engine=engine,
                      method=method, backend=label if label != "-"
                      else "factored", measurements=meas)


# -- host round path --------------------------------------------------------

def _build_host_experiment(event: bool):
    """Tiny real federation whose registry can be inflated between
    measurements: iid partition (equal shard sizes keep per-round alloc
    byte counts shape-stable), a single rank level (one train group, so
    loop counters are a deterministic function of cohort size only)."""
    from repro.federation.experiment import build_experiment
    kwargs = {}
    if event:
        from repro.federation.events import (ConstantLatency,
                                             CountTrigger, EventScheduler)
        cohort = HOST_NUM_CLIENTS // 4
        kwargs = dict(round_engine="async", pipeline_depth=1,
                      event_scheduler=EventScheduler(
                          ConstantLatency(1.0), CountTrigger(cohort)))
    else:
        kwargs = dict(round_engine="batched")
    return build_experiment(
        "raflora",
        fl_overrides={"num_rounds": 200, "num_clients": HOST_NUM_CLIENTS,
                      "participation": 0.25, "partition": "iid"},
        lora_overrides={"rank_levels": (8,), "rank_probs": (1.0,)},
        num_classes=4, d_model=32, samples_per_class=40,
        batches_per_round=1, backend="factored", **kwargs)


def _host_costs(server, rounds: int, warmup: int) -> dict:
    from repro.analysis import host_cost
    cost = host_cost.measure_rounds(server, rounds=rounds, warmup=warmup)
    return {"host_loop_iters": cost["loop_iters"],
            "host_alloc_bytes": cost["alloc_bytes"]}


def _measure_host_rows(fast: bool, verbose: bool):
    """Host-counter ScalingRows: registry ladder on the batched and
    event engines, cohort ladder on the batched engine."""
    from repro.analysis.complexity import Measurement, ScalingRow
    ks = FAST_HOST_K_LADDER if fast else HOST_K_LADDER
    cohorts = FAST_HOST_M_LADDER if fast else HOST_M_LADDER
    rows = []

    exp = _build_host_experiment(event=False)
    meas = []
    for k in ks:
        exp.registry.inflate(k)
        costs = _host_costs(exp.server, HOST_ROUNDS, HOST_WARMUP)
        meas.append(Measurement("registry", float(k), costs))
        if verbose:
            print(f"  [host] batched registry={k}: {costs}")
    fl0 = exp.server.fl
    for m in cohorts:
        exp.server.fl = dataclasses.replace(
            fl0, participation=m / HOST_NUM_CLIENTS)
        costs = _host_costs(exp.server, HOST_ROUNDS, HOST_WARMUP)
        meas.append(Measurement("m", float(m), costs))
        if verbose:
            print(f"  [host] batched cohort={m}: {costs}")
    exp.server.fl = fl0
    rows.append(ScalingRow(program="host/batched-round", engine="host",
                           method="round", backend="-",
                           measurements=meas))

    exp_ev = _build_host_experiment(event=True)
    meas_ev = []
    for k in ks:
        exp_ev.registry.inflate(k)
        costs = _host_costs(exp_ev.server, EVENT_ROUNDS, EVENT_WARMUP)
        meas_ev.append(Measurement("registry", float(k), costs))
        if verbose:
            print(f"  [host] event registry={k}: {costs}")
    rows.append(ScalingRow(program="host/event-round", engine="host",
                           method="round", backend="-",
                           measurements=meas_ev))
    return rows


# -- controls ---------------------------------------------------------------

def _add_controls(report, rows):
    from repro.analysis import complexity, host_cost
    from repro.analysis.complexity import Measurement, ScalingRow

    def dense_control():
        findings = []
        for row in rows:
            if row.backend != "dense":
                continue
            findings.extend(complexity.evaluate_row(
                row, complexity.dense_control_contracts()))
        return findings

    report.run_control(
        "dense-dn-superlinear", "scaling-contract", dense_control,
        "dense rows violate every low-rank dn contract: the ladder "
        "certifies O(d*n) and the fits can see it")

    def host_scan_control():
        meas = []
        for k in HOST_K_LADDER:
            with host_cost.HostCostMonitor() as mon:
                # the injected regression: a per-round O(registry) scan
                host_cost.tick("control/registry_scan", k)
                host_cost.alloc("control/pool_copy", 8 * k)
                mon.mark("round0")
            ph = mon.phases[0]
            meas.append(Measurement("registry", float(k), {
                "host_loop_iters": float(ph.loop_iters),
                "host_alloc_bytes": float(ph.alloc_bytes)}))
        row = ScalingRow(program="control/host-linear-scan",
                         engine="host", method="round", backend="-",
                         measurements=meas)
        return complexity.evaluate_row(row)

    report.run_control(
        "host-registry-scan", "scaling-contract", host_scan_control,
        "an injected per-round O(registry) scan trips the registry "
        "contracts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="AUDIT_scaling.json")
    ap.add_argument("--fast", action="store_true",
                    help="reduced ladders + engine subset (smoke tier)")
    ap.add_argument("--with-lint", action="store_true",
                    help="run the program-lint sweep first in the same "
                         "process (shares the lowering cache + jax init)")
    ap.add_argument("--lint-out", default="AUDIT_program_lint.json")
    ap.add_argument("--lint-skip-dispatch", action="store_true")
    ap.add_argument("--vmem-target", default=None,
                    help="pallas VMEM budget table entry for --with-lint "
                         "(v4/v5e/v5p/v6e; default v5e)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    lint_rc = 0
    if args.with_lint:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        import lint_programs
        lint_argv = ["--out", args.lint_out]
        if args.lint_skip_dispatch:
            lint_argv.append("--skip-dispatch")
        if args.vmem_target:
            lint_argv += ["--vmem-target", args.vmem_target]
        lint_rc = lint_programs.main(lint_argv)

    import jax
    from repro.analysis import complexity, lowering
    from repro.analysis.report import AuditReport, ProgramAudit

    dn = FAST_DN_LADDER if args.fast else DN_LADDER
    report = AuditReport(matrix={
        "fast": args.fast,
        "devices": jax.device_count(),
        "ladders": {
            "dn": list(dn),
            "m": list(FAST_M_LADDER if args.fast else M_LADDER),
            "r": [] if args.fast else list(R_LADDER),
            "shards": list(FAST_SHARD_LADDER if args.fast
                           else SHARD_LADDER),
            "registry": list(FAST_HOST_K_LADDER if args.fast
                             else HOST_K_LADDER),
            "host_m": list(FAST_HOST_M_LADDER if args.fast
                           else HOST_M_LADDER),
        },
        "contracts": [
            {"name": c.name, "metric": c.metric, "axis": c.axis,
             "max_slope": c.max_slope, "min_slope": c.min_slope,
             "engines": list(c.engines) if c.engines else None,
             "methods": list(c.methods) if c.methods else None,
             "backends": list(c.backends) if c.backends else None}
            for c in complexity.CONTRACTS],
    })

    rows = []
    for engine, method, label in _device_rows(args.fast):
        row = _measure_device_row(engine, method, label, args.fast)
        rows.append(row)
        findings = complexity.evaluate_row(row)
        stats = row.stats()
        base = min((m for m in row.measurements if m.axis == "dn"),
                   key=lambda m: m.x)
        stats["base_costs"] = {k: int(v) for k, v in base.costs.items()}
        audit = ProgramAudit(row.program, "scaling", findings, stats)
        report.add(audit)
        if args.verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        dn_flops = stats["slopes"].get("dn/dot_flops")
        print(f"[scal] {row.program:28s} "
              f"{'ok' if audit.ok else 'FAIL'} "
              f"(dn flops^{dn_flops})")

    for row in _measure_host_rows(args.fast, args.verbose):
        rows.append(row)
        findings = complexity.evaluate_row(row)
        audit = ProgramAudit(row.program, "scaling", findings,
                             row.stats())
        report.add(audit)
        if args.verbose or not audit.ok:
            for f in findings:
                print(f"  {f}")
        reg = row.stats()["slopes"].get("registry/host_loop_iters")
        print(f"[scal] {row.program:28s} "
              f"{'ok' if audit.ok else 'FAIL'} "
              f"(registry iters^{reg})")

    _add_controls(report, rows)

    report.write(args.out)
    s = report.summary()
    cache = lowering.cache_info()
    print(f"[scal] {s['programs']} programs, {s['errors']} errors, "
          f"{s['controls']} controls ({len(s['controls_failed'])} dead), "
          f"{cache['entries']} unique lowerings -> {args.out}")
    if not report.ok:
        for p in report.failed_programs:
            print(f"[scal] FAIL {p.program}: "
                  + "; ".join(str(f) for f in p.errors[:3]))
        for name in report.failed_controls:
            ctl = report.controls[name]
            why = ctl.error or "did not trip"
            print(f"[scal] DEAD CONTROL {name}: rule {ctl.rule} {why}")
        return 1
    print("[scal] OK" + (" (lint FAILED)" if lint_rc else ""))
    return 1 if lint_rc else 0


if __name__ == "__main__":
    sys.exit(main())
