#!/usr/bin/env bash
# CI entry point: deterministic, offline, CPU-pinned test tiers.
#
#   tools/ci.sh            # tier-1: the full suite (ROADMAP "Tier-1 verify")
#   tools/ci.sh smoke      # fast tier: skips the slow federated integration
#                          # and dry-run modules (~seconds vs ~minutes)
#   tools/ci.sh bench      # quick benchmark sweep (includes round_latency)
#
# JAX_PLATFORMS=cpu keeps runs identical on machines that also have
# accelerators; PYTHONHASHSEED pins dict/hash iteration for determinism.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONHASHSEED=0
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier="${1:-tier1}"

case "$tier" in
  tier1)
    exec python -m pytest -x -q
    ;;
  smoke)
    exec python -m pytest -x -q -k "not federation and not dryrun"
    ;;
  bench)
    exec python -m benchmarks.run --quick
    ;;
  *)
    echo "usage: tools/ci.sh [tier1|smoke|bench]" >&2
    exit 2
    ;;
esac
