#!/usr/bin/env bash
# CI entry point: deterministic, offline, CPU-pinned test tiers.
#
#   tools/ci.sh              # tier-1: the full suite (ROADMAP "Tier-1
#                            # verify") followed by the full certify sweep
#   tools/ci.sh smoke        # fast tier: skips the slow federated integration
#                            # and dry-run modules plus everything marked
#                            # @pytest.mark.slow (~seconds vs ~minutes)
#   tools/ci.sh bench        # tracked round-engine perf artifact: the full
#                            # engines x shard/pipeline-depth sweep (now incl.
#                            # the event-driven trigger sweep) under a
#                            # forced 8-virtual-device CPU platform, written
#                            # to BENCH_round_latency.json at the repo root
#   tools/ci.sh bench-check  # trend guard: snapshot the tracked artifact,
#                            # rerun the bench sweep, fail on >25% per-round
#                            # regression of existing engine x backend rows
#                            # (tools/bench_trend.py; event rows append-only)
#   tools/ci.sh bench-full   # the whole quick benchmark suite (run.py)
#   tools/ci.sh serve-smoke  # multi-tenant serving subsystem (DESIGN.md
#                            # §11): adapter store, engine equivalence,
#                            # hot-swap atomicity, scheduler tests
#   tools/ci.sh shard-smoke  # sharded round engine equivalence under a
#                            # forced 8-virtual-device CPU host platform
#   tools/ci.sh kernel-smoke # backend="kernel" engine matrix (sequential/
#                            # batched/sharded/async x every METHODS) under
#                            # a forced 8-virtual-device CPU host platform
#   tools/ci.sh transport    # compressed update transport (DESIGN.md §12):
#                            # quantize/error-feedback property + engine
#                            # matrix + checkpoint tests under 8 virtual
#                            # devices, then the fl_dryrun byte gate (int8
#                            # collective bytes must beat f32 factored)
#   tools/ci.sh lint         # program-audit sweep (DESIGN.md §8): hlo /
#                            # jaxpr / pallas / dispatch lint rules over
#                            # every engine x backend x method program plus
#                            # positive controls, written to the tracked
#                            # AUDIT_program_lint.json at the repo root
#   tools/ci.sh certify      # complexity-certifier sweep (DESIGN.md §9):
#                            # scaling exponents fitted over the geometric
#                            # size ladders and gated against the contract
#                            # catalog, written to the tracked
#                            # AUDIT_scaling.json at the repo root
#   tools/ci.sh lint-fast    # smoke-tier static analysis: the lint sweep
#                            # (dispatch audit skipped) + the certifier on
#                            # reduced ladders, sharing one in-process
#                            # lowering cache; writes to TEMP paths so the
#                            # tracked artifacts never churn. Also run as
#                            # part of `smoke`.
#   tools/ci.sh verify       # protocol-verification sweep (DESIGN.md §10):
#                            # exhaustive bounded-interleaving model check
#                            # of the event round path (checkpoint cuts at
#                            # every boundary) + the RNG/determinism lint,
#                            # written to the tracked AUDIT_protocol.json
#                            # at the repo root. Part of tier-1.
#   tools/ci.sh verify-fast  # smoke-tier protocol verification: reduced
#                            # grids/scenarios, written to a TEMP path so
#                            # the tracked artifact never churns. Also run
#                            # as part of `smoke`.
#
# JAX_PLATFORMS=cpu keeps runs identical on machines that also have
# accelerators; PYTHONHASHSEED pins dict/hash iteration for determinism.
# The persistent XLA compilation cache (also enabled by tests/conftest.py)
# makes warm reruns skip most compile time -- the dominant tier-1 cost.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONHASHSEED=0
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$PWD/.jax_cache}"

tier="${1:-tier1}"

case "$tier" in
  tier1)
    python -m pytest -x -q
    "$0" certify
    exec "$0" verify
    ;;
  smoke)
    python -m pytest -x -q -m "not slow" -k "not federation and not dryrun and not sharded_engine and not kernel_engines and not serving"
    python -m pytest -x -q -m "not slow" tests/test_serving.py
    "$0" lint-fast
    exec "$0" verify-fast
    ;;
  bench)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    exec python -m benchmarks.bench_round_latency --engine all
    ;;
  bench-check)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    baseline="$(mktemp /tmp/bench_baseline.XXXXXX.json)"
    serve_baseline="$(mktemp /tmp/bench_serve_baseline.XXXXXX.json)"
    trap 'rm -f "$baseline" "$serve_baseline"' EXIT
    cp BENCH_round_latency.json "$baseline"
    cp BENCH_serve_latency.json "$serve_baseline"
    python -m benchmarks.bench_round_latency --engine all
    python -m benchmarks.bench_serve_latency
    exec_status=0
    python tools/bench_trend.py --baseline "$baseline" \
      --fresh BENCH_round_latency.json \
      --serve-baseline "$serve_baseline" \
      --serve-fresh BENCH_serve_latency.json || exec_status=$?
    exit "$exec_status"
    ;;
  bench-full)
    exec python -m benchmarks.run --quick
    ;;
  serve-smoke)
    exec python -m pytest -x -q tests/test_serving.py
    ;;
  shard-smoke)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    exec python -m pytest -x -q tests/test_sharded_engine.py
    ;;
  kernel-smoke)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    exec python -m pytest -x -q tests/test_kernel_engines.py
    ;;
  transport)
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
      python -m pytest -x -q tests/test_transport.py
    # byte gate lowers its own 512-device mesh; do NOT export the 8-device
    # XLA_FLAGS override above it
    exec python -m repro.launch.fl_dryrun --transport int8
    ;;
  lint)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    exec python tools/lint_programs.py
    ;;
  certify)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    exec python tools/certify_scaling.py
    ;;
  lint-fast)
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    scratch="$(mktemp -d /tmp/lint_fast.XXXXXX)"
    trap 'rm -rf "$scratch"' EXIT
    python tools/certify_scaling.py --fast --with-lint --lint-skip-dispatch \
      --out "$scratch/AUDIT_scaling.json" \
      --lint-out "$scratch/AUDIT_program_lint.json"
    ;;
  verify)
    exec python tools/verify_protocol.py
    ;;
  verify-fast)
    scratch="$(mktemp -d /tmp/verify_fast.XXXXXX)"
    trap 'rm -rf "$scratch"' EXIT
    python tools/verify_protocol.py --fast \
      --out "$scratch/AUDIT_protocol.json"
    ;;
  *)
    echo "usage: tools/ci.sh [tier1|smoke|bench|bench-check|bench-full|serve-smoke|shard-smoke|kernel-smoke|transport|lint|certify|lint-fast|verify|verify-fast]" >&2
    exit 2
    ;;
esac
